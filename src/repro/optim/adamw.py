"""Pure-JAX pytree optimizers: AdamW with global-norm clipping and
schedules.  No optax dependency (offline container); state is a plain
pytree so it shards/checkpoints with the same machinery as params.

Memory note for the large dry-run configs: ``state_dtype=jnp.bfloat16``
halves the m/v footprint (llama4-400B: 4.8 TB → 2.4 TB of optimizer state
over the pod) at a negligible quality cost — this is one of the
distributed-memory tricks recorded in DESIGN.md §6 and is what lets the
400B train_4k cell fit a single 256-chip pod.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: Any = jnp.float32      # bf16 → compressed optimizer state
    schedule: str = "cosine"            # cosine | linear | const
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def linear_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * (1 - (1 - cfg.min_lr_frac) * t)


def _lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    if cfg.schedule == "cosine":
        return cosine_schedule(cfg, step)
    if cfg.schedule == "linear":
        return linear_schedule(cfg, step)
    return jnp.float32(cfg.lr)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_init(params, cfg: OptConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state: dict, params, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = _lr_at(cfg, step)
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(cfg.state_dtype), v32.astype(cfg.state_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
