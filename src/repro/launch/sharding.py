"""Sharding policies: param-path → PartitionSpec rules per model family.

The 2D policy (Megatron-TP × FSDP) for LMs:
  * 'model' (tp)  — attention heads, FFN hidden, experts, vocab
  * 'data'  (fsdp)— the complementary weight dim (params materialize
                    per-layer via XLA's all-gather, overlapped by the
                    latency-hiding scheduler)
  * batch         — ('pod','data')
Optimizer state (m/v) mirrors its parameter's spec automatically because the
rules match on the *trailing* path component names.

GNN params are replicated (KBs); edges shard over every mesh axis.
RecSys embedding tables shard rows over 'model'; dense towers replicate;
batch shards over all axes (the embedding gather is the only cross-axis op).
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import all_axes, dp_axes


def _path_names(path) -> list[str]:
    return [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]


def lm_param_spec(path, leaf, fsdp="data", tp="model") -> P:
    names = _path_names(path)
    name = names[-1]
    nd = getattr(leaf, "ndim", 0)
    inside_moe = "moe" in names

    def lead(spec_tail):
        """Prepend Nones for stacked [L, ...] params."""
        return P(*([None] * (nd - len(spec_tail)) + list(spec_tail)))

    if name == "embed":
        # vocab replicated, d over tp: a tp-sharded gather needs no
        # collectives; sharding V instead forces XLA into involuntary full
        # rematerialization of the table (observed on moonshot/internlm2)
        return P(None, tp)
    if name == "unembed":
        return P(None, tp)
    if name in ("wq", "wk", "wv"):
        return lead([fsdp, tp])
    if name == "wo":
        return lead([tp, fsdp])
    if name == "router":
        return lead([fsdp, None])
    if inside_moe and name in ("w_gate", "w_up"):
        return lead([tp, fsdp, None]) if nd >= 3 else lead([fsdp, None])
    if inside_moe and name == "w_down":
        return lead([tp, None, fsdp]) if nd >= 3 else lead([None, fsdp])
    if name in ("w_gate", "w_up"):          # dense FFN / shared experts
        return lead([fsdp, tp])
    if name == "w_down":
        return lead([tp, fsdp])
    return P(*([None] * nd))                 # norms, biases, scalars


def lm_param_spec_inference(path, leaf, fsdp="data", tp="model",
                            big_moe: bool = False) -> P:
    """Serving-time policy: NO optimizer state exists, so dense weights fit
    replicated over 'data' (TP-only) — eliminating the per-layer FSDP
    all-gathers that dominate the prefill/decode collective term.  Experts:
    E over tp; for models whose per-device expert share would still not fit
    (``big_moe``, e.g. llama4 ~50 GB/device TP-only), the expert ff dim
    shards over 'data' — the einsums then contract against resident shards
    and psum *activations* (MBs) instead of gathering *weights* (GBs)."""
    names = _path_names(path)
    name = names[-1]
    nd = getattr(leaf, "ndim", 0)
    inside_moe = "moe" in names

    def lead(spec_tail):
        return P(*([None] * (nd - len(spec_tail)) + list(spec_tail)))

    if name in ("embed", "unembed"):
        return P(None, tp)
    if name in ("wq", "wk", "wv"):
        return lead([None, tp])
    if name == "wo":
        return lead([tp, None])
    if name == "router":
        return lead([None, None])
    if inside_moe and name in ("w_gate", "w_up"):
        if nd >= 3:
            # big_moe: keep the 2D training layout (E over tp, d over fsdp)
            # — TP-only expert replication would not fit, and ff-over-fsdp
            # conflicts with dp-sharded dispatch groups on a 2D mesh
            return lead([tp, fsdp, None]) if big_moe else lead([tp, None, None])
        return lead([None, None])
    if inside_moe and name == "w_down":
        if nd >= 3:
            return lead([tp, None, fsdp]) if big_moe else lead([tp, None, None])
        return lead([None, None])
    if name in ("w_gate", "w_up"):
        return lead([None, tp])
    if name == "w_down":
        return lead([tp, None])
    return P(*([None] * nd))


def gnn_param_spec(path, leaf, **kw) -> P:
    return P(*([None] * getattr(leaf, "ndim", 0)))


def recsys_param_spec(path, leaf, tp="model", **kw) -> P:
    names = _path_names(path)
    name = names[-1]
    nd = getattr(leaf, "ndim", 0)
    if name in ("emb", "lin", "item_emb", "cat_emb"):
        return P(*([tp] + [None] * (nd - 1)))
    return P(*([None] * nd))


PARAM_SPEC_FNS = {
    "lm": lm_param_spec,
    "gnn": gnn_param_spec,
    "recsys": recsys_param_spec,
}


def tree_specs(tree, spec_fn, **kw):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_fn(path, leaf, **kw), tree)


def tree_shardings(mesh, tree, spec_fn, **kw):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_fn(path, leaf, **kw)), tree)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh, ndim: int, batch_axis: int = 0,
               axes: Optional[tuple] = None) -> P:
    axes = axes if axes is not None else dp_axes(mesh)
    spec = [None] * ndim
    spec[batch_axis] = axes
    return P(*spec)


def divisible(n: int, mesh, axes) -> bool:
    from .mesh import axis_size
    return n % axis_size(mesh, axes) == 0


def pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult
