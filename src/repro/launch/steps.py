"""Cell builder: (architecture × input shape × mesh) → lowerable step.

``build_cell`` returns a Cell carrying the jit-wrapped function, abstract
ShapeDtypeStruct arguments, and input shardings — everything ``dryrun.py``
needs to ``.lower().compile()`` and everything ``train.py`` needs to run for
real (same code path; the only difference is whether the args are abstract).

Sharding/memory decisions encoded here (see DESIGN.md §6):
  * LM train: Megatron-TP('model') × FSDP('data'), batch over ('pod','data'),
    microbatch accumulation sized so per-layer saved activations fit HBM.
  * LM decode: KV cache head-dim over 'model'; batch over dp axes when
    divisible, else (long_500k, batch=1) KV *sequence* over 'data'.
  * GNN: params replicated, edges sharded over every axis.
  * RecSys: tables row-sharded over 'model', batch over all axes.
  * ANN (the paper): index rows over 'data', queries over the remaining
    axes, exact global top-k merge.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, ShapeSpec
from repro.core import BuildParams, SearchParams
from repro.core.distributed import ShardedIndex, make_sharded_search
from repro.core.types import EMQGIndex, GraphIndex, RaBitQCodes
from repro.models import gnn as gnn_mod
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.optim import OptConfig
from repro.train import TrainState, make_train_step

from repro.models import hints

from .mesh import all_axes, axis_size, dp_axes
from .sharding import (
    PARAM_SPEC_FNS,
    lm_param_spec_inference,
    pad_to,
    tree_specs,
)


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    fn: Callable                    # already jit-wrapped with shardings
    args: tuple                     # abstract (or concrete) argument pytrees
    description: str = ""
    skip: Optional[str] = None
    model_flops: float = 0.0        # 6·N·D (dense) / 6·N_active·D (MoE) etc.
    mesh: Any = None
    policy: Optional[dict] = None   # activation-sharding hints (models/hints)

    def lower(self):
        if self.policy is not None:
            with hints.use_policy(self.mesh, self.policy):
                return self.fn.lower(*self.args)
        return self.fn.lower(*self.args)


def _lm_policy(mesh, batch_sharded: bool = True, decode: bool = False) -> dict:
    dp = dp_axes(mesh)
    if decode:
        # Decode has tiny activations and huge weights: run the MoE
        # *weight-stationary* — dispatch_groups=1 frees the 'data' axis so
        # the tile d/ff dims shard over it and the expert einsums contract
        # against locally-resident weight shards (partial-sum + psum of KBs
        # of activations) instead of FSDP-all-gathering ~2 GB of expert
        # weights per MoE layer per token step.
        pol = {
            # weight-stationary decode: tile d shards over 'data' to match
            # the resident expert shards (w_gate [E(tp), d(data), ff]) —
            # the einsums contract locally and psum KBs of activations
            "expert_tiles": P(None, "model", None, "data"),
            "expert_hidden": P(None, "model", None, None),
            "decode_q": P(dp, None, None) if batch_sharded else P(None, None, None),
        }
        if batch_sharded:
            pol |= {"act_3d": P(dp, None, None), "logits": P(dp, None, "model")}
        return pol
    if not batch_sharded:
        return {"expert_tiles": P(None, "model", None, None),
                "expert_hidden": P(None, "model", None, None)}
    return {
        "act_3d": P(dp, None, None),
        "act_heads": P(dp, None, "model", None),
        "act_kv": P(dp, None, None, None),
        "act_ff": P(dp, None, "model"),
        "logits": P(dp, None, "model"),
        "tokens_2d": P(dp, None),
        "expert_tiles": P(dp, "model", None, None),
        "expert_hidden": P(dp, "model", None, None),
    }


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        spec_tree, is_leaf=lambda x: isinstance(x, P))


def _effective_accum(batch: int, requested: int, dp: int) -> int:
    a = min(max(requested, 1), batch)
    while a > 1 and not (batch % a == 0 and (batch // a) % dp == 0):
        a -= 1
    return max(a, 1)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_state_specs(cfg, opt_cfg, mesh):
    params_shape = jax.eval_shape(lambda: tf.init(cfg, jax.random.PRNGKey(0)))
    state_shape = jax.eval_shape(
        lambda: TrainState.create(params_shape, opt_cfg))
    spec_fn = PARAM_SPEC_FNS["lm"]
    state_specs = tree_specs(state_shape, spec_fn)
    return state_shape, state_specs


def _dp_only(cfg) -> bool:
    # sub-1B models: tensor parallelism buys nothing and its tiny uneven
    # head shards (9 heads / 16 devices) cost collectives — fold the
    # 'model' axis into data parallelism instead.
    return cfg.param_count() < 1e9


def _lm_train_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    cfg: tf.LMConfig = arch.model_cfg
    B, S = shape.dims["batch"], shape.dims["seq"]
    if _dp_only(cfg):
        return _lm_train_cell_dp(arch, shape, mesh)
    dp = axis_size(mesh, dp_axes(mesh))
    cfg = dataclasses.replace(cfg, dispatch_groups=dp)
    A = _effective_accum(B, shape.accum_steps, dp)
    micro = B // A
    opt_cfg = OptConfig(
        state_dtype=jnp.bfloat16 if cfg.param_count() > 5e10 else jnp.float32,
        total_steps=10000)
    state_shape, state_specs = _lm_state_specs(cfg, opt_cfg, mesh)

    def loss(params, batch):
        return tf.loss_fn(cfg, params, batch["tokens"], batch["targets"])

    big = cfg.param_count() > 5e10
    step = make_train_step(loss, opt_cfg, accum_steps=A,
                           accum_dtype=jnp.bfloat16 if big else None)
    tok_shape = (A, micro, S) if A > 1 else (B, S)
    batch_shape = {"tokens": sds(tok_shape, jnp.int32),
                   "targets": sds(tok_shape, jnp.int32)}
    bspec = P(None, dp_axes(mesh), None) if A > 1 else P(dp_axes(mesh), None)
    batch_specs = {"tokens": bspec, "targets": bspec}

    fn = jax.jit(step,
                 in_shardings=(_named(mesh, state_specs),
                               _named(mesh, batch_specs)),
                 out_shardings=(_named(mesh, state_specs), None),
                 donate_argnums=(0,))
    # MODEL_FLOPS: 6·N_active·D for the step (fwd+bwd over all tokens)
    flops = 6.0 * cfg.active_param_count() * B * S
    return Cell(arch.id, shape.name, fn, (state_shape, batch_shape),
                description=f"train accum={A} micro={micro}",
                model_flops=flops, mesh=mesh, policy=_lm_policy(mesh))


def _is_big_moe(cfg, mesh) -> bool:
    # would TP-only expert weights overflow HBM? (bf16 bytes / tp shards)
    if not cfg.is_moe:
        return False
    n_moe = sum(1 for i in range(cfg.n_layers)
                if tf._is_moe_layer(cfg, i))
    expert_bytes = n_moe * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff * 2
    return expert_bytes / mesh.shape["model"] > 8e9


def _lm_train_cell_dp(arch: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    """Pure-DP variant for small models: batch shards over every axis,
    params/optimizer replicated; the only collective is the gradient
    all-reduce."""
    cfg: tf.LMConfig = arch.model_cfg
    B, S = shape.dims["batch"], shape.dims["seq"]
    ax = all_axes(mesh)
    if B % axis_size(mesh, ax) != 0:
        # batch can't cover every axis (multi-pod world > batch): shard
        # over the dp axes only, replicate over 'model'
        ax = dp_axes(mesh)
    world = axis_size(mesh, ax)
    A = _effective_accum(B, shape.accum_steps, world)
    micro = B // A
    opt_cfg = OptConfig(state_dtype=jnp.float32, total_steps=10000)
    params_shape = jax.eval_shape(lambda: tf.init(cfg, jax.random.PRNGKey(0)))
    state_shape = jax.eval_shape(lambda: TrainState.create(params_shape, opt_cfg))
    state_specs = jax.tree.map(lambda l: P(*([None] * l.ndim)), state_shape)

    def loss(params, batch):
        return tf.loss_fn(cfg, params, batch["tokens"], batch["targets"])

    step = make_train_step(loss, opt_cfg, accum_steps=A)
    tok_shape = (A, micro, S) if A > 1 else (B, S)
    bspec = P(None, ax, None) if A > 1 else P(ax, None)
    batch_shape = {"tokens": sds(tok_shape, jnp.int32),
                   "targets": sds(tok_shape, jnp.int32)}
    fn = jax.jit(step,
                 in_shardings=(_named(mesh, state_specs),
                               {"tokens": NamedSharding(mesh, bspec),
                                "targets": NamedSharding(mesh, bspec)}),
                 out_shardings=(_named(mesh, state_specs), None),
                 donate_argnums=(0,))
    policy = {"act_3d": P(ax, None, None), "logits": P(ax, None, None),
              "act_heads": P(ax, None, None, None),
              "act_kv": P(ax, None, None, None),
              "act_ff": P(ax, None, None), "tokens_2d": P(ax, None)}
    return Cell(arch.id, shape.name, fn, (state_shape, batch_shape),
                description=f"train DP-only accum={A} micro={micro}",
                model_flops=6.0 * cfg.active_param_count() * B * S,
                mesh=mesh, policy=policy)


def _lm_prefill_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    cfg: tf.LMConfig = arch.model_cfg
    B, S = shape.dims["batch"], shape.dims["seq"]
    cfg = dataclasses.replace(cfg, dispatch_groups=axis_size(mesh, dp_axes(mesh)))
    big = _is_big_moe(cfg, mesh)
    params_shape = jax.eval_shape(lambda: tf.init(cfg, jax.random.PRNGKey(0)))
    # inference param specs: no optimizer state at serve time → dense
    # weights replicate over 'data' (TP-only), killing the per-layer FSDP
    # weight gathers the loop-aware analysis shows dominate serving
    # collectives; big-MoE experts shard ff over 'data' (weight-stationary)
    p_specs = tree_specs(params_shape, lm_param_spec_inference, big_moe=big)
    toks = sds((B, S), jnp.int32)
    fn = jax.jit(partial(tf.prefill, cfg),
                 in_shardings=(_named(mesh, p_specs),
                               NamedSharding(mesh, P(dp_axes(mesh), None))))
    flops = 2.0 * cfg.active_param_count() * B * S
    # sequence-parallel residual stream (Megatron-SP): between blocks the
    # [B, S, d] activations shard S over 'model', so the TP combines become
    # reduce-scatters and the residual memory drops tp-fold.
    policy = dict(_lm_policy(mesh))
    policy["act_3d"] = P(dp_axes(mesh), "model", None)
    return Cell(arch.id, shape.name, fn, (params_shape, toks),
                description=f"prefill seq-parallel infer-specs big={big}",
                model_flops=flops, mesh=mesh, policy=policy)


def _lm_decode_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    cfg: tf.LMConfig = arch.model_cfg
    B, S = shape.dims["batch"], shape.dims["seq"]
    dp = dp_axes(mesh)
    dp_sz = axis_size(mesh, dp)
    cfg = dataclasses.replace(cfg, dispatch_groups=1)  # weight-stationary EP
    big = _is_big_moe(cfg, mesh)
    params_shape = jax.eval_shape(lambda: tf.init(cfg, jax.random.PRNGKey(0)))
    p_specs = tree_specs(params_shape, lm_param_spec_inference, big_moe=big)
    cache_shape = jax.eval_shape(lambda: tf.init_cache(cfg, B, S))

    batch_ok = B % dp_sz == 0 and B >= dp_sz
    # cache head-dim shards over 'model'.  (S-over-'model' "flash-decoding"
    # was measured and REFUTED: the per-token dynamic cache write at a
    # runtime position cannot target a sharded S dim, so XLA reshards the
    # whole cache every layer — loop-aware collective 1.9 s/step vs ~50 MB
    # score all-reduces for the hd-sharded layout.  §Perf iteration log.)
    kv_spec = (P(None, dp, None, None, "model") if batch_ok
               else P(None, None, "data", None, "model"))
    vec_spec = P(dp) if batch_ok else P(None)

    def cache_spec(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        if name == "pos":
            return vec_spec
        return kv_spec

    c_specs = jax.tree_util.tree_map_with_path(cache_spec, cache_shape)
    toks = sds((B,), jnp.int32)
    decode_policy = _lm_policy(mesh, batch_sharded=batch_ok, decode=True)
    # pin the per-layer cache slice layout inside the scan body — without
    # this the partitioner reshards the [B,S,KV,hd] slice every layer on
    # GQA archs (observed 2 GB/layer of involuntary cache movement)
    decode_policy["cache_kv"] = P(*kv_spec[1:])
    fn = jax.jit(partial(tf.decode_step, cfg),
                 in_shardings=(_named(mesh, p_specs), _named(mesh, c_specs),
                               NamedSharding(mesh, vec_spec)),
                 out_shardings=(None, _named(mesh, c_specs)),
                 donate_argnums=(1,))
    flops = 2.0 * cfg.active_param_count() * B  # one token per sequence
    return Cell(arch.id, shape.name, fn, (params_shape, cache_shape, toks),
                description=f"decode kv={'batch' if batch_ok else 'seq'}-sharded",
                model_flops=flops, mesh=mesh, policy=decode_policy)





# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    cfg: gnn_mod.GATConfig = arch.model_cfg[shape.name]
    dims = shape.dims
    ax = all_axes(mesh)
    world = axis_size(mesh, ax)
    opt_cfg = OptConfig(total_steps=1000)

    dp = dp_axes(mesh)
    dp_sz = axis_size(mesh, dp)
    if shape.kind == "molecule":
        n_nodes = dims["batch"] * dims["n_nodes"]
        n_edges = pad_to(dims["batch"] * dims["n_edges"], dp_sz)
        n_graphs = dims["batch"]
    elif shape.kind == "minibatch":
        n_nodes = dims["pad_nodes"]
        n_edges = pad_to(dims["pad_edges"], dp_sz)
        n_graphs = 0
    else:
        n_nodes = dims["n_nodes"]
        n_edges = pad_to(dims["n_edges"], dp_sz)
        n_graphs = 0

    params_shape = jax.eval_shape(lambda: gnn_mod.init(cfg, jax.random.PRNGKey(0)))
    state_shape = jax.eval_shape(lambda: TrainState.create(params_shape, opt_cfg))
    state_specs = tree_specs(state_shape, PARAM_SPEC_FNS["gnn"])

    batch_shape = {
        "x": sds((n_nodes, dims["d_feat"]), jnp.float32),
        "src": sds((n_edges,), jnp.int32),
        "dst": sds((n_edges,), jnp.int32),
    }
    # edges shard over dp axes; node/head tensors shard heads over 'model'
    # (constrained inside gnn._gat_layer via the policy below)
    batch_specs = {"x": P(None, None), "src": P(dp), "dst": P(dp)}
    if shape.kind == "molecule":
        batch_shape |= {
            "graph_ids": sds((n_nodes,), jnp.int32),
            "labels": sds((n_graphs,), jnp.int32),
            "label_mask": sds((n_graphs,), jnp.bool_),
            "node_mask": sds((n_nodes,), jnp.bool_),
        }
        batch_specs |= {"graph_ids": P(None), "labels": P(None),
                        "label_mask": P(None), "node_mask": P(None)}
    else:
        batch_shape |= {
            "labels": sds((n_nodes,), jnp.int32),
            "label_mask": sds((n_nodes,), jnp.bool_),
        }
        batch_specs |= {"labels": P(None), "label_mask": P(None)}

    def loss(params, batch):
        return gnn_mod.loss_fn(
            cfg, params, batch["x"], batch["src"], batch["dst"],
            batch["labels"], batch["label_mask"],
            graph_ids=batch.get("graph_ids"), n_graphs=n_graphs,
            node_mask=batch.get("node_mask"))

    step = make_train_step(loss, opt_cfg)
    fn = jax.jit(step,
                 in_shardings=(_named(mesh, state_specs),
                               _named(mesh, batch_specs)),
                 out_shardings=(_named(mesh, state_specs), None),
                 donate_argnums=(0,))
    # model flops ≈ 3 × fwd; fwd ≈ E·H·(2d_msg) + N·d_in·H·d_out (SpMM+SDDMM)
    d_out = cfg.d_hidden * cfg.n_heads
    flops = 3.0 * (2.0 * n_edges * d_out * 2 + 2.0 * n_nodes *
                   cfg.d_in * d_out + 2.0 * n_nodes * d_out * cfg.n_classes)
    policy = {
        "gnn_nodes_hd": P(None, "model", None),
        "gnn_nodes_h": P(None, "model"),
        "gnn_edges_h": P(dp, None),
    }
    return Cell(arch.id, shape.name, fn, (state_shape, batch_shape),
                description=f"{shape.kind} E={n_edges}", model_flops=flops,
                mesh=mesh, policy=policy)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_batch(arch: ArchSpec, B: int, for_train: bool):
    cfg = arch.model_cfg
    if arch.id == "fm":
        b = {"sparse_ids": sds((B, cfg.n_sparse), jnp.int32)}
    elif arch.id == "dcn-v2":
        b = {"dense": sds((B, cfg.n_dense), jnp.float32),
             "sparse_ids": sds((B, cfg.n_sparse), jnp.int32)}
    elif arch.id == "dien":
        T = cfg.seq_len
        b = {"hist_items": sds((B, T), jnp.int32),
             "hist_cats": sds((B, T), jnp.int32),
             "hist_mask": sds((B, T), jnp.bool_),
             "target_item": sds((B,), jnp.int32),
             "target_cat": sds((B,), jnp.int32)}
    elif arch.id == "mind":
        T = cfg.seq_len
        b = {"hist_items": sds((B, T), jnp.int32),
             "hist_mask": sds((B, T), jnp.bool_)}
        if for_train:
            b |= {"target_item": sds((B,), jnp.int32),
                  "neg_items": sds((B, cfg.n_neg), jnp.int32)}
    else:
        raise KeyError(arch.id)
    if for_train and arch.id != "mind":
        b["label"] = sds((B,), jnp.float32)
    return b


_RECSYS_LOSS = {
    "fm": lambda cfg, p, b: rs.fm_loss(cfg, p, b),
    "dcn-v2": lambda cfg, p, b: rs.dcn_loss(cfg, p, b),
    "dien": lambda cfg, p, b: rs.dien_loss(cfg, p, b),
    "mind": lambda cfg, p, b: rs.mind_loss(cfg, p, b),
}

_RECSYS_INIT = {
    "fm": rs.fm_init, "dcn-v2": rs.dcn_init, "dien": rs.dien_init,
    "mind": rs.mind_init,
}


def _recsys_model_flops(arch: ArchSpec, B: int) -> float:
    cfg = arch.model_cfg
    if arch.id == "fm":
        return B * (2.0 * cfg.n_sparse * cfg.embed_dim * 2)
    if arch.id == "dcn-v2":
        d = cfg.d_input
        mlp = sum(2.0 * a * b for a, b in
                  zip((d,) + cfg.mlp_dims[:-1], cfg.mlp_dims))
        return B * (cfg.n_cross * 2.0 * d * d + mlp)
    if arch.id == "dien":
        g, db, T = cfg.gru_dim, cfg.d_beh, cfg.seq_len
        gru = 2.0 * T * 3 * (db * g + g * g) + 2.0 * T * 3 * (g * g + g * g)
        mlp = 2.0 * (g + 2 * db) * cfg.mlp_dims[0] + 2.0 * cfg.mlp_dims[0] * cfg.mlp_dims[1]
        return B * (gru + mlp)
    if arch.id == "mind":
        d, T, K = cfg.embed_dim, cfg.seq_len, cfg.n_interests
        return B * (2.0 * T * d * d + cfg.routing_iters * 4.0 * T * K * d)
    return 0.0


def _recsys_train_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    cfg = arch.model_cfg
    B = shape.dims["batch"]
    ax = all_axes(mesh)
    opt_cfg = OptConfig(total_steps=100000)
    params_shape = jax.eval_shape(
        lambda: _RECSYS_INIT[arch.id](cfg, jax.random.PRNGKey(0)))
    state_shape = jax.eval_shape(lambda: TrainState.create(params_shape, opt_cfg))
    state_specs = tree_specs(state_shape, PARAM_SPEC_FNS["recsys"])
    batch_shape = _recsys_batch(arch, B, for_train=True)
    batch_specs = jax.tree.map(
        lambda s: P(*([ax] + [None] * (len(s.shape) - 1))), batch_shape)

    loss = partial(_RECSYS_LOSS[arch.id], cfg)
    step = make_train_step(lambda p, b: loss(p, b), opt_cfg)
    fn = jax.jit(step,
                 in_shardings=(_named(mesh, state_specs),
                               _named(mesh, batch_specs)),
                 out_shardings=(_named(mesh, state_specs), None),
                 donate_argnums=(0,))
    return Cell(arch.id, shape.name, fn, (state_shape, batch_shape),
                description="train", model_flops=3 * _recsys_model_flops(arch, B))


def _recsys_serve_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    cfg = arch.model_cfg
    B = shape.dims["batch"]
    ax = all_axes(mesh)
    params_shape = jax.eval_shape(
        lambda: _RECSYS_INIT[arch.id](cfg, jax.random.PRNGKey(0)))
    p_specs = tree_specs(params_shape, PARAM_SPEC_FNS["recsys"])
    batch_shape = _recsys_batch(arch, B, for_train=False)
    batch_specs = jax.tree.map(
        lambda s: P(*([ax] + [None] * (len(s.shape) - 1))), batch_shape)

    if arch.id == "fm":
        f = lambda p, b: rs.fm_forward(cfg, p, b["sparse_ids"])
    elif arch.id == "dcn-v2":
        f = lambda p, b: rs.dcn_forward(cfg, p, b["dense"], b["sparse_ids"])
    elif arch.id == "dien":
        f = lambda p, b: rs.dien_forward(cfg, p, b)
    else:  # mind: user-interest inference
        f = lambda p, b: rs.mind_user_interests(cfg, p, b["hist_items"],
                                                b["hist_mask"])
    fn = jax.jit(f, in_shardings=(_named(mesh, p_specs),
                                  _named(mesh, batch_specs)))
    return Cell(arch.id, shape.name, fn, (params_shape, batch_shape),
                description="serve", model_flops=_recsys_model_flops(arch, B))


def _recsys_retrieval_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    cfg = arch.model_cfg
    B, C = shape.dims["batch"], shape.dims["n_candidates"]
    ax = all_axes(mesh)
    C = pad_to(C, axis_size(mesh, ax))
    params_shape = jax.eval_shape(
        lambda: _RECSYS_INIT[arch.id](cfg, jax.random.PRNGKey(0)))
    p_specs = tree_specs(params_shape, PARAM_SPEC_FNS["recsys"])
    cand = sds((C,), jnp.int32)
    cand_spec = NamedSharding(mesh, P(ax))

    if arch.id == "fm":
        user = sds((B, cfg.n_sparse - 1), jnp.int32)
        f = lambda p, u, c: rs.fm_retrieval(cfg, p, u, c, k=100)
        args = (params_shape, user, cand)
        ins = (_named(mesh, p_specs), NamedSharding(mesh, P(None, None)), cand_spec)
        flops = B * C * 2.0 * cfg.embed_dim
    elif arch.id == "dcn-v2":
        dense = sds((B, cfg.n_dense), jnp.float32)
        user = sds((B, cfg.n_sparse - 1), jnp.int32)
        f = lambda p, d, u, c: rs.dcn_retrieval(cfg, p, d, u, c, k=100)
        args = (params_shape, dense, user, cand)
        ins = (_named(mesh, p_specs), NamedSharding(mesh, P(None, None)),
               NamedSharding(mesh, P(None, None)), cand_spec)
        flops = _recsys_model_flops(arch, C)
    elif arch.id == "dien":
        batch_shape = {"hist_items": sds((B, cfg.seq_len), jnp.int32),
                       "hist_cats": sds((B, cfg.seq_len), jnp.int32),
                       "hist_mask": sds((B, cfg.seq_len), jnp.bool_)}
        f = lambda p, b, c: rs.dien_retrieval(cfg, p, b, c, k=100)
        args = (params_shape, batch_shape, cand)
        ins = (_named(mesh, p_specs),
               jax.tree.map(lambda s: NamedSharding(mesh, P(None, None)),
                            batch_shape), cand_spec)
        flops = _recsys_model_flops(arch, C)
    else:  # mind
        batch_shape = {"hist_items": sds((B, cfg.seq_len), jnp.int32),
                       "hist_mask": sds((B, cfg.seq_len), jnp.bool_)}
        f = lambda p, b, c: rs.mind_retrieval(cfg, p, b["hist_items"],
                                              b["hist_mask"], c, k=100)
        args = (params_shape, batch_shape, cand)
        ins = (_named(mesh, p_specs),
               jax.tree.map(lambda s: NamedSharding(mesh, P(None, None)),
                            batch_shape), cand_spec)
        flops = (_recsys_model_flops(arch, B)
                 + B * cfg.n_interests * C * 2.0 * cfg.embed_dim)
    fn = jax.jit(f, in_shardings=ins)
    return Cell(arch.id, shape.name, fn, args,
                description=f"retrieval C={C}", model_flops=flops)


# ---------------------------------------------------------------------------
# ANN (the paper's own config) cells
# ---------------------------------------------------------------------------

def abstract_sharded_emqg(n_total: int, dim: int, M: int, n_shards: int
                          ) -> ShardedIndex:
    n_local = pad_to(int(math.ceil(n_total / n_shards)), 8)
    W = (dim + 31) // 32
    graph = GraphIndex(
        vectors=sds((n_shards, n_local, dim), jnp.float32),
        neighbors=sds((n_shards, n_local, M), jnp.int32),
        medoid=sds((n_shards,), jnp.int32),
        kind="delta_emqg", delta=0.0)
    codes = RaBitQCodes(
        codes=sds((n_shards, n_local, W), jnp.uint32),
        norms=sds((n_shards, n_local), jnp.float32),
        ip_xo=sds((n_shards, n_local), jnp.float32),
        rotation=sds((n_shards, dim, dim), jnp.float32),
        center=sds((n_shards, dim), jnp.float32),
        dim=dim)
    return ShardedIndex(index=EMQGIndex(graph=graph, codes=codes),
                        offsets=sds((n_shards,), jnp.int32), n_total=n_total,
                        sizes=sds((n_shards,), jnp.int32))


def _ann_serve_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    mc = arch.model_cfg
    B = shape.dims["batch"]
    shard_axes = ("data",)
    q_axes = tuple(a for a in mesh.axis_names if a not in shard_axes)
    n_shards = axis_size(mesh, shard_axes)
    sidx = abstract_sharded_emqg(mc["n"], mc["dim"],
                                 mc["build"].max_degree, n_shards)
    queries = sds((B, mc["dim"]), jnp.float32)
    run = make_sharded_search(mesh, shard_axes=shard_axes,
                              query_axis=q_axes or None,
                              merge="all_gather", quantized=True)
    params: SearchParams = mc["search"]
    fn = jax.jit(lambda s, q: run(s, q, params))
    # model flops: probing search work ≈ hops·M·(bit-unpack+dot) + exact d²;
    # report the exact-rerank-equivalent dense cost as the useful-work floor
    flops = B * n_shards * params.l_max * 2.0 * mc["dim"]
    return Cell(arch.id, shape.name, fn, (sidx, queries),
                description=f"δ-EMQG sharded serve S={n_shards}",
                model_flops=flops)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def build_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    if shape.skip:
        return Cell(arch.id, shape.name, fn=None, args=(), skip=shape.skip)
    if arch.family == "lm":
        if shape.kind == "train":
            return _lm_train_cell(arch, shape, mesh)
        if shape.kind == "prefill":
            return _lm_prefill_cell(arch, shape, mesh)
        if shape.kind == "decode":
            return _lm_decode_cell(arch, shape, mesh)
    if arch.family == "gnn":
        return _gnn_cell(arch, shape, mesh)
    if arch.family == "recsys":
        if shape.kind == "train":
            return _recsys_train_cell(arch, shape, mesh)
        if shape.kind == "serve":
            return _recsys_serve_cell(arch, shape, mesh)
        if shape.kind == "retrieval":
            return _recsys_retrieval_cell(arch, shape, mesh)
    if arch.family == "ann":
        return _ann_serve_cell(arch, shape, mesh)
    raise KeyError(f"no builder for {arch.family}/{shape.kind}")
