"""Loop-aware HLO analysis: FLOPs / HBM bytes / collective wire bytes with
while-loop trip-count multipliers.

Why this exists: ``compiled.cost_analysis()`` and a flat scan of the HLO
text both count the *static* instructions — but a scan-over-layers model
executes its loop body n_layers times (and a gradient-accumulation scan
multiplies again).  For a 48-layer LM that under-counts compute and
collective traffic by ~50×, which silently corrupts every roofline term.

This module parses the post-SPMD optimized HLO text into computations,
resolves the call graph (while bodies/conditions, fusion calls), extracts
loop trip counts from the loop-condition constants, and accumulates:

  * flops            — 2·|result|·K for every ``dot`` (K = contracted dims
                       of the lhs operand, resolved via the per-computation
                       symbol table)
  * hbm_bytes        — Σ (operands + result) over memory-moving ops
                       (fusions, dots, gathers/scatters, dynamic slices,
                       copies, collectives) — an HBM-traffic proxy that
                       treats each fused region as one load/store unit
  * collective_bytes — ring-model wire bytes per collective
                       (see _wire_bytes), × loop multipliers

Known approximations (documented in EXPERIMENTS.md §Roofline):
  * trip counts come from the largest integer constant in the loop
    condition — exact for lax.scan/fori loops, which is all we emit;
  * CPU-backend HLO upcasts bf16 dots to f32, inflating both bytes and the
    gathered-weight collectives ≈2× vs a real TPU compile.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(
    r"\b(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)"
    r"\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+([a-z][\w\-]*)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_CALLED_RE = re.compile(r"(?:body|condition|calls|to_apply|branch_computations)="
                        r"\{?%?([\w.\-]+(?:,\s*%[\w.\-]+)*)\}?")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_MEM_OPS = {"fusion", "dot", "gather", "scatter", "dynamic-slice",
            "dynamic-update-slice", "copy", "convert", "transpose",
            "reduce", "broadcast", "iota", "concatenate", "select-and-scatter",
            "convolution", "sort", "reduce-window", "pad", "slice",
            "reverse", "rng", "cholesky", "triangular-solve",
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute", "all-gather-start", "all-reduce-start",
            "collective-permute-start"}


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = bytes_ = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str          # everything after the opening paren

    def operands(self) -> list[str]:
        # operand refs appear before the closing paren of the call
        depth, end = 1, len(self.rest)
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return re.findall(r"%([\w.\-]+)", self.rest[:end])


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    shapes: dict       # instr name → type_str (includes parameters)

    def trip_count(self) -> int:
        """For a loop-*condition* computation: the bound constant."""
        consts = []
        for i in self.instrs:
            if i.opcode == "constant":
                m = re.match(r"(\d+)", i.rest)
                if m:
                    consts.append(int(m.group(1)))
        return max(consts) if consts else 1


def parse_computations(hlo: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = ""
    for line in hlo.splitlines():
        stripped = line.rstrip()
        # computation header: "[ENTRY ]%name (args) -> type {"
        if stripped.endswith("{") and ") -> " in stripped and \
                (stripped.startswith("%") or stripped.startswith("ENTRY")):
            hdr = _COMP_HDR_RE.match(stripped)
            if hdr:
                cur = Computation(hdr.group(1), [], {})
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, type_str, opcode, rest = m.groups()
            cur.instrs.append(Instr(name, type_str, opcode, rest))
            cur.shapes[name] = type_str
        # parameter lines: "%p = f32[8,16]{1,0} parameter(0)"
        pm = re.match(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+parameter\(", line)
        if pm:
            cur.shapes[pm.group(1)] = pm.group(2)
    return comps, entry


def _called(instr: Instr) -> list[str]:
    out = []
    for m in _CALLED_RE.finditer(instr.rest):
        for name in m.group(1).split(","):
            out.append(name.strip().lstrip("%"))
    return out


def compute_multipliers(comps: dict[str, Computation], entry: str
                        ) -> tuple[dict[str, float], dict[str, int]]:
    """Per-computation execution-count multiplier (while bodies × trip) and
    the trip count of each loop body (for stacked-operand accounting)."""
    mult: dict[str, float] = defaultdict(float)
    trips: dict[str, int] = {}

    def visit(name: str, m: float, depth=0):
        if name not in comps or depth > 60:
            return
        mult[name] += m
        comp = comps[name]
        for instr in comp.instrs:
            if instr.opcode == "while":
                w = _WHILE_RE.search(instr.rest)
                if w:
                    cond, body = w.group(1), w.group(2)
                    trip = comps[cond].trip_count() if cond in comps else 1
                    trips[body] = max(trips.get(body, 1), trip)
                    visit(cond, m * trip, depth + 1)
                    visit(body, m * trip, depth + 1)
                continue
            for c in _called(instr):
                visit(c, m, depth + 1)

    visit(entry, 1.0)
    return dict(mult), trips


def _dot_flops(instr: Instr, shapes: dict) -> float:
    out_elems, _ = _shape_elems_bytes(instr.type_str)
    ops = instr.operands()
    if not ops:
        return 0.0
    lhs_shape = _shape_dims(shapes.get(ops[0], ""))
    if lhs_shape is None:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
    k = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            di = int(d)
            if di < len(lhs_shape):
                k *= lhs_shape[di]
    return 2.0 * out_elems * k


def _wire_bytes(instr: Instr) -> float:
    _, result_bytes = _shape_elems_bytes(instr.type_str)
    if instr.opcode.endswith("-start"):
        result_bytes /= 2          # tuple of (operand, result)
    g = 2
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", instr.rest)
    if m:
        g = int(m.group(2))
    else:
        m = re.search(r"replica_groups=\{\{([0-9,]+)\}", instr.rest)
        if m:
            g = len(m.group(1).split(","))
    op = instr.opcode.replace("-start", "")
    if op == "all-gather":
        return result_bytes * (g - 1) / g
    if op == "all-reduce":
        return 2 * result_bytes * (g - 1) / g
    if op == "reduce-scatter":
        return result_bytes * (g - 1)
    if op == "all-to-all":
        return result_bytes * (g - 1) / g
    return result_bytes               # collective-permute


def _mem_bytes(instr: Instr, shapes: dict, trip: int = 1) -> float:
    """HBM traffic proxy: result + operand bytes.  Inside a loop body, an
    operand whose leading dim equals the trip count is a stacked
    per-iteration operand (scan weights / microbatches): each iteration
    reads one slice, so it is charged operand/trip."""
    if instr.opcode not in _MEM_OPS:
        return 0.0
    _, out_b = _shape_elems_bytes(instr.type_str)
    total = float(out_b)
    for op in instr.operands()[:8]:
        if op in shapes:
            dims = _shape_dims(shapes[op])
            _, b = _shape_elems_bytes(shapes[op])
            if trip > 1 and dims and dims[0] == trip:
                b = b / trip
            total += b
    return total


def analyze(hlo: str) -> dict:
    comps, entry = parse_computations(hlo)
    if not entry:   # fall back: computation with the most instructions
        entry = max(comps, key=lambda c: len(comps[c].instrs))
    mult, trips = compute_multipliers(comps, entry)
    # computations called via fusion `calls=` are counted at the call site
    # (their operands/results ARE the HBM traffic); internal ops are not
    # separate HBM round-trips.
    fusion_bodies = set()
    for comp in comps.values():
        for instr in comp.instrs:
            if instr.opcode == "fusion":
                fusion_bodies.update(_called(instr))

    flops = 0.0
    hbm_bytes = 0.0
    coll = {c: {"count": 0.0, "operand_bytes": 0.0} for c in COLLECTIVES}
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        trip = trips.get(cname, 1)
        in_fusion = cname in fusion_bodies
        for instr in comp.instrs:
            if instr.opcode == "dot":
                flops += m * _dot_flops(instr, comp.shapes)
            base_op = instr.opcode.replace("-start", "")
            if base_op in COLLECTIVES and not instr.opcode.endswith("-done"):
                coll[base_op]["count"] += m
                coll[base_op]["operand_bytes"] += m * _wire_bytes(instr)
            if not in_fusion:
                hbm_bytes += m * _mem_bytes(instr, comp.shapes, trip)
    coll_total = sum(v["operand_bytes"] for v in coll.values())
    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collective_bytes": coll_total,
        "collectives": {k: {"count": v["count"],
                            "operand_bytes": v["operand_bytes"]}
                        for k, v in coll.items()},
        "n_computations": len(comps),
    }
