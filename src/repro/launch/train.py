"""Production training entry point.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --shape train_4k --steps 100 --ckpt-dir /tmp/ckpt [--smoke]

On a real TPU slice this builds the production mesh and runs the same cell
the dry-run compiled; with ``--smoke`` (CPU) it runs the arch's reduced
config on the host mesh with a scaled-down batch — the full fault-tolerance
loop (deterministic data cursor, periodic async checkpoints, auto-resume)
is identical in both modes.

Fault tolerance model (DESIGN.md §6):
  * data batches are pure functions of (seed, step) → restart replays
    exactly the post-checkpoint stream;
  * checkpoints are atomic + manifest-committed; torn saves are skipped at
    restore;
  * on restart with a different device count, restore_latest reshards onto
    the new mesh (elastic resize).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.data import lm_batch, make_markov_lm, recsys_ctr_batch, recsys_seq_batch
from repro.models import transformer as tf
from repro.optim import OptConfig
from repro.train import TrainState, make_train_step


def _lm_smoke_loop(arch, steps, ckpt_dir, batch=16, seq=64, lr=1e-3):
    cfg = arch.smoke_cfg
    opt = OptConfig(lr=lr, total_steps=max(steps, 10), warmup_steps=min(20, steps // 5 + 1))
    params = tf.init(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(
        lambda p, b: tf.loss_fn(cfg, p, b["tokens"], b["targets"]), opt))
    state = TrainState.create(params, opt)
    mgr = CheckpointManager(ckpt_dir, every=max(steps // 5, 10), keep=3)
    start, state = mgr.restore(state)
    start = int(state.step)
    if start:
        print(f"[train] resumed from step {start}")
    lm = make_markov_lm(cfg.vocab, branch=4, seed=0)
    t0 = time.time()
    for s in range(start, steps):
        toks, tgts = lm_batch(lm, batch, seq, s, seed=0)
        state, m = step_fn(state, {"tokens": jnp.asarray(toks),
                                   "targets": jnp.asarray(tgts)})
        if s % 10 == 0 or s == steps - 1:
            print(f"[train] step {s}: loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} "
                  f"({(s - start + 1) / (time.time() - t0):.1f} steps/s) "
                  f"floor={lm.entropy():.3f}")
        mgr.maybe_save(s + 1, state)
    mgr.wait()
    return state


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on host devices (CPU demo)")
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if args.smoke or len(jax.devices()) < 256:
        if arch.family != "lm":
            raise SystemExit("smoke train loop currently drives LM archs; "
                             "see examples/ for gnn/recsys training")
        _lm_smoke_loop(arch, args.steps, args.ckpt_dir)
        return 0

    # full-scale path: the dry-run cell, executed for real
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    mesh = make_production_mesh(multi_pod=len(jax.devices()) >= 512)
    cell = build_cell(arch, arch.shapes[args.shape], mesh)
    print(f"[train] lowered {arch.id} × {args.shape} on {mesh.devices.size} chips")
    compiled = cell.lower().compile()
    print(compiled.memory_analysis())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
