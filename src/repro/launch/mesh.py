"""Production mesh construction.

Defined as a FUNCTION (not a module-level constant) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, while smoke tests must keep seeing 1 device.

Mesh shapes per the assignment:
  single-pod : (16, 16)      axes ("data", "model")        — 256 chips
  multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

Axis roles:
  pod   — pod-level data parallelism (gradient all-reduce crosses DCN/ICI
          once per step; serving shards the request stream here)
  data  — in-pod data parallel + FSDP parameter sharding
  model — tensor/expert/vocab parallel (+ KV-head-dim sharding for decode)
"""

from __future__ import annotations

import jax

try:  # jax ≥ 0.5: explicit-sharding axis types
    from jax.sharding import AxisType

    def _axis_kw(n_axes: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n_axes}
except ImportError:  # jax 0.4.x: Auto is the only (implicit) behavior

    def _axis_kw(n_axes: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n // 2, 2) if n % 2 == 0 and n > 1 else (n, 1)
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def all_axes(mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    out = 1
    for n in names:
        out *= mesh.shape[n]
    return out
