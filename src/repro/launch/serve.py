"""ANN serving entry point — builds (or loads) a δ-EMQG index and serves a
query stream through the batched request loop.

    PYTHONPATH=src python -m repro.launch.serve --n 4000 --dim 48 \
        --queries 512 --alpha 1.2 --k 10

At production scale the same loop drives ``core.distributed``'s sharded
index across the mesh (see examples/vector_serve.py for the multi-shard
CPU demonstration)."""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import BuildParams, SearchParams, build_emqg
from repro.core.distances import brute_force_knn
from repro.data import clustered_vectors
from repro.serve import AnnServer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--dim", type=int, default=48)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--alpha", type=float, default=1.2)
    ap.add_argument("--max-degree", type=int, default=24)
    ap.add_argument("--beam", type=int, default=64)
    args = ap.parse_args(argv)

    print(f"[serve] building δ-EMQG over n={args.n} d={args.dim} …")
    base = clustered_vectors(args.n, args.dim, 48, seed=0)
    t0 = time.time()
    idx = build_emqg(base, BuildParams(
        max_degree=args.max_degree, beam_width=args.beam,
        t=args.beam // 2, iters=2, block=1024, align_degree=True))
    print(f"[serve] built in {time.time() - t0:.1f}s "
          f"(mean degree {float(np.asarray(idx.graph.degrees()).mean()):.1f})")

    queries = clustered_vectors(args.queries, args.dim, 48, seed=1)
    gt_d, gt_i = brute_force_knn(queries, base, args.k)
    srv = AnnServer(idx, SearchParams(k=args.k, l0=args.k, l_max=256,
                                      alpha=args.alpha, adaptive=True,
                                      max_hops=2048),
                    max_batch=128, buckets=(32, 128))
    srv.submit_many(queries)
    results = srv.drain()
    ids = np.stack([r[0] for r in results])
    rec = np.mean([len(set(ids[i].tolist()) & set(gt_i[i].tolist())) / args.k
                   for i in range(len(results))])
    print(f"[serve] {srv.stats.n_requests} requests in "
          f"{srv.stats.n_batches} batches; recall@{args.k}={rec:.4f}; "
          f"QPS={srv.stats.qps:.1f} (CPU proxy); "
          f"p_max_latency={srv.stats.max_latency_s * 1e3:.1f} ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
