"""ANN serving entry point — builds (or loads) a δ-EMQG index and serves a
query stream through the batched request loop.

    PYTHONPATH=src python -m repro.launch.serve --n 4000 --dim 48 \
        --queries 512 --alpha 1.2 --k 10

``--resilient`` runs the same stream through the resilience layer
(admission control, per-request deadlines, error-bounded degradation
ladder, circuit-breaker fallback — see ``repro.serve.resilience``) and
reports the resilience counters plus the worst δ error bound any response
was served under.

``--metrics`` attaches the unified observability layer (``repro.obs``):
the server emits the standard serve taxonomy (request-latency / queue-wait
histograms with p50/p95/p99, per-status response counters, degradation /
breaker transition counters, batch-aggregated ``n_dist_comps``/``n_hops``
Exp-5 counters, shard-liveness gauges, WAL timing families) plus
per-request spans, and the run ends with a Prometheus-text and a JSON
snapshot on stdout.  ``--metrics-every S`` additionally prints a one-line
stderr summary at most every S seconds while draining (implies
``--metrics``).

``--shards N`` serves a sharded index over N devices through
``ShardedResilientAnnServer``; ``--kill-shards 1,2`` stages a mid-stream
shard loss and ``--auto-repair`` (with ``--repair-budget`` /
``--store-dir``) lets the ``core.repair`` controller rebuild the lost
shards from a durable vector store, verify, and atomically re-install them
— the printed coverage trajectory returns to 1.0 without operator action.

At production scale the same loop drives ``core.distributed``'s sharded
index across the mesh (see examples/vector_serve.py for the multi-shard
CPU demonstration)."""

from __future__ import annotations

import argparse
import math
import time

import numpy as np

from repro.core import BuildParams, SearchParams, build_emqg
from repro.core.distances import brute_force_knn
from repro.data import clustered_vectors
from repro.obs import (
    MetricsRegistry,
    PeriodicSummary,
    Tracer,
    declare_serve_metrics,
    to_json,
    to_prometheus,
)
from repro.serve import AnnServer, ResilienceConfig, ResilientAnnServer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--dim", type=int, default=48)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--alpha", type=float, default=1.2)
    ap.add_argument("--max-degree", type=int, default=24)
    ap.add_argument("--beam", type=int, default=64)
    ap.add_argument("--delta", type=float, default=None,
                    help="fixed construction δ (default: adaptive δ_t rule; "
                         "a fixed δ makes the reported error bounds finite)")
    ap.add_argument("--resilient", action="store_true",
                    help="serve through the resilience layer")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline (resilient mode)")
    ap.add_argument("--max-queue", type=int, default=4096,
                    help="admission-control queue cap (resilient mode)")
    ap.add_argument("--degrade-at", type=int, default=64,
                    help="queue depth that steps the ladder down one rung")
    ap.add_argument("--recover-at", type=int, default=8,
                    help="queue depth that steps the ladder back up")
    ap.add_argument("--rungs", type=int, default=4,
                    help="degradation-ladder depth (resilient mode)")
    ap.add_argument("--audit", action="store_true",
                    help="run the graph-invariant auditor (core.verify) on "
                         "the built index before serving; non-zero exit on "
                         "violations")
    ap.add_argument("--metrics", action="store_true",
                    help="attach the obs layer; print Prometheus-text and "
                         "JSON metric snapshots after serving")
    ap.add_argument("--metrics-every", type=float, default=0.0,
                    help="emit a one-line stderr metrics summary at most "
                         "every S seconds while serving (implies --metrics)")
    ap.add_argument("--shards", type=int, default=0,
                    help="serve a sharded δ-EMQG over N devices (0 = "
                         "single-node).  Needs N visible devices — on CPU "
                         "set XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N")
    ap.add_argument("--kill-shards", default="",
                    help="comma-separated shard ids killed after the first "
                         "third of the stream (sharded-mode chaos demo)")
    ap.add_argument("--auto-repair", action="store_true",
                    help="self-heal killed shards: rebuild from a durable "
                         "ShardVectorStore, verify, atomically install "
                         "(sharded mode)")
    ap.add_argument("--repair-budget", type=int, default=1,
                    help="max repair attempts per sweep (--auto-repair)")
    ap.add_argument("--store-dir", default=None,
                    help="ShardVectorStore directory (--auto-repair; "
                         "default: a temp dir created for the run)")
    args = ap.parse_args(argv)

    registry = tracer = summary = None
    if args.metrics or args.metrics_every > 0:
        registry = declare_serve_metrics(MetricsRegistry(),
                                         n_shards=max(args.shards, 1))
        tracer = Tracer()
        summary = PeriodicSummary(registry, args.metrics_every)

    if args.shards:
        return _serve_sharded(args, registry, tracer)

    print(f"[serve] building δ-EMQG over n={args.n} d={args.dim} …")
    base = clustered_vectors(args.n, args.dim, 48, seed=0)
    t0 = time.perf_counter()
    idx = build_emqg(base, BuildParams(
        max_degree=args.max_degree, beam_width=args.beam, delta=args.delta,
        t=args.beam // 2, iters=2, block=1024, align_degree=True),
        metrics=registry)
    print(f"[serve] built in {time.perf_counter() - t0:.1f}s "
          f"(mean degree {float(np.asarray(idx.graph.degrees()).mean()):.1f})")

    if args.audit:
        from repro.core.verify import audit
        rep = audit(idx.graph)
        print(rep.summary())
        if not rep.ok:
            return 1

    queries = clustered_vectors(args.queries, args.dim, 48, seed=1)
    gt_d, gt_i = brute_force_knn(queries, base, args.k)
    params = SearchParams(k=args.k, l0=args.k, l_max=256, alpha=args.alpha,
                          adaptive=True, max_hops=2048)

    def drive(srv, queries):
        """Submit + drain, chunked when a periodic summary is live so the
        heartbeat can fire between batches of a long replay."""
        if summary is None or summary.every_s <= 0:
            srv.submit_many(queries)
            return srv.drain()
        out = []
        chunk = max(srv.max_batch, 1)
        for s in range(0, len(queries), chunk):
            srv.submit_many(queries[s : s + chunk])
            out.extend(srv.drain())
            summary.tick()
        summary.tick(force=True)
        return out

    if args.resilient:
        cfg = ResilienceConfig(
            max_queue=args.max_queue,
            deadline_s=None if args.deadline_ms is None
            else args.deadline_ms / 1e3,
            degrade_depth=args.degrade_at, recover_depth=args.recover_at,
            n_rungs=args.rungs)
        srv = ResilientAnnServer(idx, params, config=cfg,
                                 max_batch=128, buckets=(32, 128),
                                 metrics=registry, tracer=tracer)
        responses = drive(srv, queries)
        served = [(i, r) for i, r in enumerate(responses) if r.ok]
        ids = np.stack([r.ids for _, r in served]) if served else np.zeros((0, args.k))
        rec = np.mean([
            len(set(ids[j].tolist()) & set(gt_i[i].tolist())) / args.k
            for j, (i, _) in enumerate(served)]) if served else 0.0
        bounds = [r.delta_bound for _, r in served]
        worst = max(bounds) if bounds else math.inf
        s = srv.stats
        print(f"[serve] {s.n_requests} served / {len(responses)} submitted "
              f"in {s.n_batches} batches; recall@{args.k}={rec:.4f}; "
              f"QPS={s.qps:.1f} (CPU proxy); "
              f"p_max_latency={s.max_latency_s * 1e3:.1f} ms")
        print(f"[serve] resilience: shed={s.n_shed} rejected={s.n_rejected} "
              f"degraded={s.n_degraded} retried={s.n_retried} "
              f"fallback={s.n_fallback} deadline_missed={s.n_deadline_missed} "
              f"failed={s.n_failed}; worst δ bound="
              f"{worst if math.isfinite(worst) else 'unbounded (δ unknown)'}")
        _dump_metrics(registry, tracer)
        return 0

    srv = AnnServer(idx, params, max_batch=128, buckets=(32, 128),
                    metrics=registry, tracer=tracer)
    results = drive(srv, queries)
    ids = np.stack([r[0] for r in results])
    rec = np.mean([len(set(ids[i].tolist()) & set(gt_i[i].tolist())) / args.k
                   for i in range(len(results))])
    print(f"[serve] {srv.stats.n_requests} requests in "
          f"{srv.stats.n_batches} batches; recall@{args.k}={rec:.4f}; "
          f"QPS={srv.stats.qps:.1f} (CPU proxy); "
          f"p_max_latency={srv.stats.max_latency_s * 1e3:.1f} ms")
    _dump_metrics(registry, tracer)
    return 0


def _serve_sharded(args, registry, tracer) -> int:
    """Sharded serving with optional mid-stream shard kills and self-healing
    repair — the CLI face of ``core.repair`` + ``ShardedResilientAnnServer``.

    The stream runs in three stages: healthy third, then ``--kill-shards``
    lands, then the tail — with ``--auto-repair`` the repair controller
    rebuilds the killed shards from the vector store before the next batch
    dispatches, so the printed coverage trajectory returns to 1.0 without
    an operator call."""
    import tempfile

    import jax
    from jax.sharding import Mesh

    from repro.core.distributed import build_sharded
    from repro.serve import ShardedResilientAnnServer

    devs = jax.devices()
    if len(devs) < args.shards:
        print(f"[serve] need {args.shards} devices, have {len(devs)} — "
              "on CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
              f"{args.shards}")
        return 2
    mesh = Mesh(np.array(devs[: args.shards]), ("data",))
    bp = BuildParams(max_degree=args.max_degree, beam_width=args.beam,
                     delta=args.delta, t=args.beam // 2, iters=2, block=1024,
                     align_degree=True)
    print(f"[serve] building sharded δ-EMQG: n={args.n} d={args.dim} "
          f"S={args.shards} …")
    base = clustered_vectors(args.n, args.dim, 48, seed=0)
    t0 = time.perf_counter()
    sidx = build_sharded(base, args.shards, bp, quantized=True, seed=0)
    print(f"[serve] built in {time.perf_counter() - t0:.1f}s")

    store_dir = None
    if args.auto_repair:
        from repro.core.repair import ShardVectorStore
        store_dir = args.store_dir or tempfile.mkdtemp(prefix="shard_store_")
        ShardVectorStore.create(store_dir, base, args.shards, bp,
                                quantized=True, seed=0)
        print(f"[serve] vector store at {store_dir}")

    queries = clustered_vectors(args.queries, args.dim, 48, seed=1)
    gt_d, gt_i = brute_force_knn(queries, base, args.k)
    params = SearchParams(k=args.k, l0=args.k, l_max=256, alpha=args.alpha,
                          adaptive=True, max_hops=2048)
    repair_cfg = None
    if args.auto_repair:
        from repro.core.repair import RepairConfig
        repair_cfg = RepairConfig(budget_per_sweep=args.repair_budget)
    srv = ShardedResilientAnnServer(
        sidx, params, mesh, quantized=True, max_batch=128,
        buckets=(32, 128), metrics=registry, tracer=tracer,
        auto_repair=repair_cfg, vector_store=store_dir)

    kill = [int(x) for x in args.kill_shards.split(",") if x.strip()]
    stages = np.array_split(np.arange(len(queries)), 3)
    responses, coverage_traj = [], []
    for stage, idxs in enumerate(stages):
        if stage == 1 and kill:
            for s in kill:
                srv.kill_shard(s)
            print(f"[serve] killed shards {kill} "
                  f"(coverage now {srv.coverage:.2f})")
        if idxs.size:
            srv.submit_many(queries[idxs])
            responses.extend(srv.drain())
        coverage_traj.append(srv.coverage)
    served = [(i, r) for i, r in enumerate(responses) if r.ok]
    rec = np.mean([
        len(set(r.ids.tolist()) & set(gt_i[i].tolist())) / args.k
        for i, r in served]) if served else 0.0
    worst_cov = min((r.coverage for _, r in served), default=1.0)
    print(f"[serve] {len(served)} served / {len(responses)} submitted; "
          f"recall@{args.k}={rec:.4f}; coverage trajectory "
          f"{[round(c, 2) for c in coverage_traj]} (worst response "
          f"{worst_cov:.2f})")
    if srv.repair is not None:
        print(f"[serve] repair: {srv.repair.n_repaired} repaired, "
              f"{srv.repair.n_failed} failed attempts, "
              f"{srv.repair.n_sweeps} sweeps; final coverage "
              f"{srv.coverage:.2f}")
    elif kill:
        print(f"[serve] no auto-repair: coverage stays {srv.coverage:.2f} "
              "until an operator rebuilds")
    _dump_metrics(registry, tracer)
    return 0


def _dump_metrics(registry, tracer) -> None:
    if registry is None:
        return
    print("=== metrics (prometheus text) ===")
    print(to_prometheus(registry), end="")
    print("=== metrics (json) ===")
    print(to_json(registry, tracer))


if __name__ == "__main__":
    raise SystemExit(main())
