"""Launch layer: production mesh, sharding policies, per-cell step builders,
dry-run driver, and train/serve entry points."""
