import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is ordinary.

"""Multi-pod dry-run driver.

For every (architecture × input shape) cell, on the single-pod 16×16 mesh
and the 2×16×16 multi-pod mesh:

    lowered  = jit(step, in_shardings=...).lower(*abstract_args)
    compiled = lowered.compile()
    memory_analysis()   → per-device bytes (proves the cell fits HBM)
    cost_analysis()     → HLO FLOPs / bytes for §Roofline
    parse compiled HLO  → per-collective operand bytes for §Roofline

Results are appended to a JSON file (default
``benchmarks/results/dryrun.json``) that ``benchmarks/roofline.py`` reads.

Usage:
    python -m repro.launch.dryrun                       # everything
    python -m repro.launch.dryrun --arch internlm2-20b  # one arch
    python -m repro.launch.dryrun --arch sift1m --mesh single
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax

# TPU v5e hardware model (assignment constants)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dims_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(dims_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def parse_collectives(hlo_text: str) -> dict:
    """Per-device wire bytes for every collective in the partitioned module.

    Optimized HLO prints operands as bare %refs, so sizes come from the
    *result* shape (per-device/partitioned), converted to ring-model wire
    traffic with the replica-group size g:
        all-gather        out·(g−1)/g      (result = gathered; recv share)
        all-reduce        2·out·(g−1)/g    (reduce-scatter + all-gather)
        reduce-scatter    out·(g−1)        (input = out·g, ring pass)
        all-to-all        out·(g−1)/g
        collective-permute out              (one send per device)
    '-start' async halves are counted once ('-done' carries no new data).
    """
    out = {c: {"count": 0, "operand_bytes": 0} for c in _COLLECTIVES}
    pat = re.compile(r"=\s+(.+?)\s+(" + "|".join(_COLLECTIVES) + r")(-start)?\(")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if not m:
            continue
        op = m.group(2)
        result_bytes = _shape_bytes(m.group(1))
        if m.group(3):  # '-start' result is a tuple (operand, result, ...)
            result_bytes = result_bytes / 2
        g = _group_size(line)
        if op == "all-gather":
            wire = result_bytes * (g - 1) / g
        elif op == "all-reduce":
            wire = 2 * result_bytes * (g - 1) / g
        elif op == "reduce-scatter":
            wire = result_bytes * (g - 1)
        elif op == "all-to-all":
            wire = result_bytes * (g - 1) / g
        else:  # collective-permute
            wire = result_bytes
        out[op]["count"] += 1
        out[op]["operand_bytes"] += int(wire)
    out["total_operand_bytes"] = sum(v["operand_bytes"]
                                     for k, v in out.items() if isinstance(v, dict))
    return out


def run_cell(arch, shape, mesh, mesh_name: str, verbose: bool = True) -> dict:
    from repro.launch.steps import build_cell

    rec = {"arch": arch.id, "shape": shape.name, "mesh": mesh_name,
           "chips": mesh.devices.size}
    if shape.skip:
        rec["status"] = "skip"
        rec["skip_reason"] = shape.skip
        if verbose:
            print(f"  [{mesh_name}] {arch.id} × {shape.name}: SKIP ({shape.skip})")
        return rec
    t0 = time.time()
    try:
        from repro.launch.hlo_analysis import analyze

        cell = build_cell(arch, shape, mesh)
        lowered = cell.lower()
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)       # static (per-HLO-op) view
        loop = analyze(hlo)                 # loop-aware (×trip-count) view
        chips = mesh.devices.size
        raw_flops = float(cost.get("flops", 0.0))
        raw_bytes = float(cost.get("bytes accessed", 0.0))
        # roofline terms from the loop-aware analysis (cost_analysis counts
        # while bodies ONCE — ~50× under for scan-over-layers models; see
        # launch/hlo_analysis.py)
        t_comp = loop["flops"] / PEAK_FLOPS
        t_mem = loop["hbm_bytes"] / HBM_BW
        t_coll = loop["collective_bytes"] / LINK_BW
        per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                         + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        rec.update({
            "status": "ok",
            "description": cell.description,
            "compile_s": round(time.time() - t0, 1),
            "model_flops": cell.model_flops,
            "raw_cost_analysis": {"flops": raw_flops,
                                  "bytes_accessed": raw_bytes},
            "hlo_flops_per_device": loop["flops"],
            "hlo_bytes_per_device": loop["hbm_bytes"],
            "collectives_static": coll,
            "collectives": loop["collectives"]
            | {"total_operand_bytes": loop["collective_bytes"]},
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_per_device_bytes": per_dev_bytes,
            },
            "roofline": {
                "compute_s": t_comp,
                "memory_s": t_mem,
                "collective_s": t_coll,
                "bottleneck": max(
                    (("compute", t_comp), ("memory", t_mem),
                     ("collective", t_coll)), key=lambda kv: kv[1])[0],
                "useful_flops_ratio": (cell.model_flops / (loop["flops"] * chips)
                                       if loop["flops"] else 0.0),
            },
        })
        if verbose:
            r = rec["roofline"]
            print(f"  [{mesh_name}] {arch.id} × {shape.name}: OK "
                  f"({rec['compile_s']}s) mem/dev="
                  f"{per_dev_bytes/2**30:.2f}GiB "
                  f"comp={r['compute_s']*1e3:.2f}ms "
                  f"mem={r['memory_s']*1e3:.2f}ms "
                  f"coll={r['collective_s']*1e3:.2f}ms "
                  f"→ {r['bottleneck']}")
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"  [{mesh_name}] {arch.id} × {shape.name}: "
                  f"ERROR {rec['error'][:300]}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id filter")
    ap.add_argument("--shape", default=None, help="shape name filter")
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    ap.add_argument("--out", default="benchmarks/results/dryrun.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs import all_archs, get_arch
    from repro.launch.mesh import make_production_mesh

    assert len(jax.devices()) == 512, (
        f"dry-run needs 512 placeholder devices, got {len(jax.devices())}")

    archs = [get_arch(args.arch)] if args.arch else all_archs()
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    records = []
    for arch in archs:
        for shape_name, shape in arch.shapes.items():
            if args.shape and shape_name != args.shape:
                continue
            for mesh_name, mesh in meshes:
                records.append(run_cell(arch, shape, mesh, mesh_name))
                jax.clear_caches()

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    existing = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            existing = json.load(f)
        keys = {(r["arch"], r["shape"], r["mesh"]) for r in records}
        existing = [r for r in existing
                    if (r["arch"], r["shape"], r["mesh"]) not in keys]
    with open(args.out, "w") as f:
        json.dump(existing + records, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skip" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skip, {n_err} error "
          f"→ {args.out}")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
