"""Filtered (predicate-constrained) error-bounded search.

Production vector stores almost always serve *filtered* queries ("nearest
documents WHERE tenant = t").  On a proximity graph the standard robust
strategy is post-filter-during-traversal: traverse the unfiltered graph
(filtering edges breaks monotonicity and with it the δ-EMG guarantee) but
maintain the result set over passing nodes only, with the candidate window
auto-widened by the filter's selectivity.

The filter is a per-node bitmask (callers precompute it from their
metadata).  The adaptive stop rule (Alg. 3's α) is applied to the
*filtered* candidate list, so the (1/δ′) certificate transfers to the
filtered ground truth whenever the usual local-optimum condition holds for
the unfiltered traversal — the monotonic descent into the δ-neighborhood
is a property of the graph, not of the result filter.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .search import SearchParams, search
from .types import GraphIndex, SearchResult


@partial(jax.jit, static_argnames=("k",))
def _filter_topk(ids, dists, mask, k: int):
    """Keep the k closest candidates whose filter bit is set."""
    ok = jnp.where(ids >= 0, jnp.take(mask, jnp.maximum(ids, 0)), False)
    d = jnp.where(ok, dists, jnp.inf)
    neg, idx = jax.lax.top_k(-d, k)
    out_ids = jnp.take_along_axis(ids, idx, axis=-1)
    out_d = -neg
    return jnp.where(jnp.isfinite(out_d), out_ids, -1), out_d


def filtered_search(graph: GraphIndex, queries, filter_mask, k: int,
                    alpha: float = 1.2, l_max: int = 256,
                    selectivity: Optional[float] = None,
                    max_hops: int = 4096) -> SearchResult:
    """Error-bounded top-k among nodes with ``filter_mask[id] == True``.

    ``selectivity`` (fraction of passing nodes; estimated from the mask when
    omitted) sizes the traversal: the unfiltered search must see ~k/sel
    candidates for k filtered survivors.
    """
    mask = jnp.asarray(filter_mask, bool)
    sel = float(selectivity if selectivity is not None
                else max(float(jnp.mean(mask)), 1e-3))
    k_wide = int(min(l_max, max(k + 4, int(np.ceil(1.5 * k / sel)))))
    p = SearchParams(k=k_wide, l0=k_wide, l_max=max(l_max, k_wide),
                     alpha=alpha, adaptive=True, max_hops=max_hops)
    res, cand_ids, cand_dists = search(graph, jnp.asarray(queries), p,
                                       with_candidates=True)
    ids, dists = _filter_topk(cand_ids, cand_dists, mask, k)
    return SearchResult(ids=ids, dists=dists,
                        n_dist_comps=res.n_dist_comps,
                        n_approx_comps=res.n_approx_comps,
                        n_hops=res.n_hops, final_l=res.final_l,
                        saturated=res.saturated,
                        n_encounters=res.n_encounters)
