"""Baseline index builders the paper compares against (Sec. 7, Exp-1/2/9).

All baselines share the ``GraphIndex`` container and the occlusion machinery
in ``geometry.py`` — each is a different pruning rule (or insertion order)
over the same candidate-generation substrate, exactly mirroring how the
paper's C++ baselines share the NSG codebase:

* ``build_knn_graph``  — plain top-M kNN graph (GNNS/IEH substrate).
* ``build_nsg``        — MRNG lune rule (δ→0), greedy-search candidates,
                         reverse edges + connectivity repair.
* ``build_taumg``      — τ-MG shifted-lune rule.
* ``build_vamana``     — DiskANN robust-prune (α ≥ 1) rule.
* ``build_nsw``        — navigable small world via wave-batched incremental
                         insertion (flat; HNSW's hierarchy is an entry-point
                         accelerator we replace with the medoid start — noted
                         in DESIGN.md).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .build_approx import BuildParams, build_approx
from .distances import brute_force_knn, medoid as find_medoid, pairwise_sqdist
from .geometry import select_neighbors
from .search import SearchParams, search
from .types import GraphIndex


def build_knn_graph(vectors, k: int = 32) -> GraphIndex:
    vectors = jnp.asarray(vectors, jnp.float32)
    _, ids = brute_force_knn(vectors, vectors, min(k, vectors.shape[0] - 1),
                             exclude_self=True)
    med = find_medoid(vectors)
    return GraphIndex(vectors=vectors, neighbors=jnp.asarray(ids),
                      medoid=jnp.int32(med), kind="knn")


def build_nsg(vectors, max_degree: int = 32, beam_width: int = 64,
              iters: int = 2, **kw) -> GraphIndex:
    p = BuildParams(max_degree=max_degree, beam_width=beam_width, iters=iters,
                    delta=0.0, rule="mrng", **kw)
    g = build_approx(vectors, p)
    return dataclasses.replace(g, kind="nsg")


def build_taumg(vectors, tau: float = 0.05, max_degree: int = 32,
                beam_width: int = 64, iters: int = 2, **kw) -> GraphIndex:
    p = BuildParams(max_degree=max_degree, beam_width=beam_width, iters=iters,
                    delta=tau, rule="tau_mg", **kw)
    g = build_approx(vectors, p)
    return dataclasses.replace(g, kind="tau_mg", delta=tau)


def build_vamana(vectors, alpha: float = 1.2, max_degree: int = 32,
                 beam_width: int = 64, iters: int = 2, **kw) -> GraphIndex:
    p = BuildParams(max_degree=max_degree, beam_width=beam_width, iters=iters,
                    delta=alpha, rule="vamana", **kw)
    g = build_approx(vectors, p)
    return dataclasses.replace(g, kind="vamana", delta=alpha)


def build_nsw(vectors, max_degree: int = 32, ef: int = 64,
              wave: int = 256, seed: int = 0) -> GraphIndex:
    """Flat NSW by wave-batched incremental insertion.

    Waves trade strict sequentiality for batched accelerator searches: every
    point in a wave searches the graph built from all previous waves, then
    connects bidirectionally to its ef-best candidates (top max_degree).
    """
    vectors = jnp.asarray(vectors, jnp.float32)
    vectors_np = np.asarray(vectors)
    n = vectors.shape[0]
    M = max_degree
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)

    nbr = np.full((n, M), -1, np.int32)
    deg = np.zeros(n, np.int32)

    # seed clique
    seed_sz = min(M + 1, n)
    seed_ids = order[:seed_sz]
    d2 = np.asarray(pairwise_sqdist(jnp.asarray(vectors_np[seed_ids]),
                                    jnp.asarray(vectors_np[seed_ids])))
    for i, u in enumerate(seed_ids):
        others = np.argsort(d2[i])
        picks = [int(seed_ids[j]) for j in others if seed_ids[j] != u][: M]
        nbr[u, : len(picks)] = picks
        deg[u] = len(picks)

    inserted = list(seed_ids)
    pos = seed_sz
    while pos < n:
        wave_ids = order[pos : pos + wave]
        sub_vecs = jnp.asarray(vectors_np[inserted])
        sub_nbr_np = nbr[inserted].copy()
        # remap global ids → local subgraph ids
        remap = -np.ones(n, np.int64)
        remap[inserted] = np.arange(len(inserted))
        valid = sub_nbr_np >= 0
        sub_nbr_np = np.where(valid, remap[np.maximum(sub_nbr_np, 0)], -1)
        sub = GraphIndex(sub_vecs, jnp.asarray(sub_nbr_np.astype(np.int32)),
                         jnp.int32(0), kind="nsw")
        p = SearchParams(k=min(M, len(inserted)), l0=ef, l_max=ef,
                         adaptive=False, max_hops=4 * ef)
        res = search(sub, jnp.asarray(vectors_np[wave_ids]), p)
        ids_local = np.asarray(res.ids)
        inserted_arr = np.asarray(inserted)
        for j, u in enumerate(wave_ids):
            cands = ids_local[j]
            cands = inserted_arr[cands[cands >= 0]][:M]
            nbr[u, : len(cands)] = cands
            deg[u] = len(cands)
            for v in cands:  # reverse link — never destructive: replacing a
                # full node's farthest link strips the early long-range edges
                # NSW navigation depends on (observed: 2.7% reachability)
                if deg[v] < M:
                    nbr[v, deg[v]] = u
                    deg[v] += 1
        inserted.extend(int(u) for u in wave_ids)
        pos += len(wave_ids)

    med = find_medoid(vectors)
    from .build_approx import _repair_connectivity

    _repair_connectivity(vectors_np, nbr, deg, M, med)
    return GraphIndex(vectors=vectors, neighbors=jnp.asarray(nbr),
                      medoid=jnp.int32(med), kind="nsw")


BUILDERS = {
    "knn": build_knn_graph,
    "nsg": build_nsg,
    "tau_mg": build_taumg,
    "vamana": build_vamana,
    "nsw": build_nsw,
}
