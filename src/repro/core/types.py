"""Core pytree datatypes for the δ-EMG framework.

All index structures are JAX pytrees so they can be donated, sharded with
``NamedSharding`` and passed through ``jit``/``shard_map`` unchanged.  Static
hyper-parameters (degree cap, δ, …) live in the aux data so retracing only
happens when the *shape* of the index changes, never per query.

Conventions
-----------
* Neighbor lists are fixed-width ``int32[n, M]`` padded with ``INVALID_ID``.
* Distances are *squared* Euclidean internally (monotone in true distance);
  public APIs report true distances.  Squared form saves an rsqrt per
  candidate in the hot loop and keeps the occlusion predicates polynomial.
* ``INVALID_ID = -1``; invalid slots always carry ``+inf`` distance.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

INVALID_ID = jnp.int32(-1)
INF = jnp.float32(jnp.inf)


def _register(cls):
    """Register a dataclass as a pytree, splitting array/static fields."""
    data_fields = [f.name for f in dataclasses.fields(cls) if not f.metadata.get("static")]
    meta_fields = [f.name for f in dataclasses.fields(cls) if f.metadata.get("static")]
    return jax.tree_util.register_dataclass(cls, data_fields, meta_fields)


def static_field(**kw):
    return dataclasses.field(metadata={"static": True}, **kw)


@_register
@dataclasses.dataclass(frozen=True)
class GraphIndex:
    """A proximity graph over a vector dataset.

    Attributes
    ----------
    vectors:   ``f32[n, d]`` the base dataset (row ``i`` = vector of node ``i``).
    neighbors: ``int32[n, M]`` fixed-width adjacency, padded with ``INVALID_ID``.
    medoid:    ``int32[]`` default entry point for searches.
    kind:      static tag — "delta_emg" | "mrng" | "tau_mg" | "vamana" |
               "nsw" | "knn" (used for reporting only).
    delta:     static — the construction δ (0 for rule families without one).
    """

    vectors: jax.Array
    neighbors: jax.Array
    medoid: jax.Array
    kind: str = static_field(default="delta_emg")
    delta: float = static_field(default=0.0)

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def max_degree(self) -> int:
        return self.neighbors.shape[1]

    def degrees(self) -> jax.Array:
        return jnp.sum(self.neighbors >= 0, axis=1)


@_register
@dataclasses.dataclass(frozen=True)
class RaBitQCodes:
    """RaBitQ 1-bit-per-dimension quantization state.

    ``codes`` packs sign bits of the rotated, centered vectors 32-dims per
    uint32 lane (little-endian within the lane:  bit ``j`` of word ``w``
    is dimension ``32*w + j``).

    Per-vector scalars required by the unbiased estimator:
      * ``norms``  — ``‖v − c‖``            (f32[n])
      * ``ip_xo``  — ``⟨x̄, o⟩``             (f32[n]) where ``o=(v−c)/‖v−c‖``
                     and ``x̄ = sign(P(v−c))/√d``.
    ``rotation`` is the shared orthogonal matrix ``P`` (f32[d, d]) and
    ``center`` the shared centroid ``c`` (f32[d]).
    """

    codes: jax.Array        # uint32[n, ceil(d/32)]
    norms: jax.Array        # f32[n]
    ip_xo: jax.Array        # f32[n]
    rotation: jax.Array     # f32[d, d]
    center: jax.Array       # f32[d]
    dim: int = static_field(default=0)

    @property
    def n(self) -> int:
        return self.codes.shape[0]

    @property
    def words(self) -> int:
        return self.codes.shape[1]


@_register
@dataclasses.dataclass(frozen=True)
class EMQGIndex:
    """δ-EMQG = δ-EMG graph + RaBitQ codes (Sec. 6 of the paper)."""

    graph: GraphIndex
    codes: RaBitQCodes

    @property
    def n(self) -> int:
        return self.graph.n

    @property
    def dim(self) -> int:
        return self.graph.dim


@_register
@dataclasses.dataclass(frozen=True)
class SearchResult:
    """Batched search output.

    ids / dists are ``[B, k]`` (true Euclidean distances, ascending).
    ``n_dist_comps`` counts *exact* distance evaluations per query — the
    paper's Exp-5 efficiency metric.  ``n_approx_comps`` counts quantized
    evaluations (δ-EMQG only).  ``n_hops`` counts expansions.
    ``n_encounters`` counts candidate *encounters*: every valid neighbor id
    produced by an expansion (plus every probed candidate, for the probing
    engine) *before* dedup.  The beam engine's packed bitset never
    re-evaluates a pruned-then-reencountered node, so its ``n_dist_comps``
    undercounts relative to the paper's Exp-5 counter; ``n_encounters`` is
    dedup-independent and identical across engines at ``beam_width=1``.
    ``saturated`` flags queries whose adaptive ``l`` hit the buffer cap
    before the α-stop rule fired (bound may not hold for those).
    """

    ids: jax.Array
    dists: jax.Array
    n_dist_comps: jax.Array
    n_approx_comps: jax.Array
    n_hops: jax.Array
    final_l: jax.Array
    saturated: jax.Array
    n_encounters: jax.Array = None


@_register
@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Static search hyper-parameters (hashable → one trace per setting)."""

    k: int = static_field(default=10)
    l0: int = static_field(default=16)          # initial candidate width (≥ k)
    l_max: int = static_field(default=128)      # buffer capacity / adaptive cap
    l_step: int = static_field(default=1)       # adaptive growth per outer round
    alpha: float = static_field(default=1.0)    # α stop rule (Alg. 3); 1.0 = greedy
    adaptive: bool = static_field(default=False)  # False → Alg. 1, True → Alg. 3
    max_hops: int = static_field(default=512)   # hard iteration cap (also T ring size)
    rerank: bool = static_field(default=True)   # δ-EMQG: exact rerank of results
    beam_width: int = static_field(default=1)   # frontier nodes expanded per hop (W)


def take_rows(mat: jax.Array, ids: jax.Array) -> jax.Array:
    """Gather rows with INVALID_ID-safe indexing (invalid → row 0, caller masks)."""
    safe = jnp.where(ids >= 0, ids, 0)
    return jnp.take(mat, safe, axis=0)


@partial(jax.jit, static_argnames=("k",))
def topk_smallest(dists: jax.Array, ids: jax.Array, k: int):
    """Return the k smallest (dist, id) pairs, ascending, along the last axis."""
    neg, idx = jax.lax.top_k(-dists, k)
    return -neg, jnp.take_along_axis(ids, idx, axis=-1)
