"""Self-healing shards: detect → rebuild → verify → atomically install.

The serving stack already *detects* shard loss (``ShardHealthRegistry`` +
``DeadlineHealthChecker``) and *degrades* with explicit accounting
(coverage / max_missed).  This module closes the loop: a dead replica is
automatically rebuilt from a durable vector source and re-enters serving —
without an operator — once the rebuilt graph is verified.

Components
----------
``ShardVectorStore``
    Durable per-shard vector source.  ``create`` snapshots the contiguous
    row partition (the exact padded rows ``build_sharded`` feeds each
    shard's builder, via ``distributed.shard_rows``) as one npz + manifest
    per shard, with the same integrity conventions as
    ``checkpoint/manager.py``: tmp + fsync + ``os.replace`` writes, per-file
    CRC32 in the manifest, verify-on-read.  A corrupted source fails loudly
    (``ShardSourceCorruptError``) instead of rebuilding a wrong shard.

``RepairController``
    Watches the registry for dead replicas and repairs them under a
    per-sweep budget.  One repair is a **two-phase** state machine:

    contained phase (any failure → backoff + retry, slot stays dead)
        load_source → rebuild (``distributed.build_shard``: same per-shard
        seed derivation as ``build_sharded``, so the rebuilt index is
        bit-identical to the original build) → audit (``core.verify``
        invariants) → spot-check (``host_reference_merge`` restricted to
        the candidate slot: ids in range, self-probes return their own row)

    install phase (atomic-install rule)
        install the candidate ``ShardedIndex`` (one pytree slot replaced)
        → ``mark_live``.  The participation mask flips *only after* the
        verified index is installed, so serving can never route to a
        half-installed or unverified shard: a crash before the install
        leaves the old index and a dead slot; a crash between install and
        ``mark_live`` leaves a verified index in a slot the mask still
        excludes.  Either way liveness never regresses and the next sweep
        retries.

    Failures back off exponentially (``backoff_s · 2^(attempt−1)``, capped)
    on the injectable monotonic clock, so tests schedule retries without
    sleeping.  Fault injection: ``fault_hook(point)`` fires at
    ``load_source`` / ``rebuild`` (contained — exceptions there are treated
    as repair failures) and ``before_install`` / ``mid_install`` /
    ``after_install`` (NOT contained — a raising hook simulates the process
    dying there, the ``testing.faults.RepairFaultPlan`` convention).

Observability (all through ``obs``): ``repair_started_total`` /
``repair_succeeded_total`` / ``repair_failed_total`` counters,
``shard_under_repair{shard}`` gauge (1 from first attempt until success),
``repair_duration_seconds`` histogram (successful repairs), and
``repair_started`` / ``repair_succeeded`` / ``repair_failed`` structured
events.  All timing uses the injected monotonic clock — never wall time.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional

import jax
import numpy as np

from .build_approx import BuildParams
from .distributed import (ShardedIndex, ShardHealthRegistry, build_shard,
                          host_reference_merge, shard_rows)
from .types import EMQGIndex, SearchParams
from .updates import _atomic_write, _crc
from .verify import audit


class ShardSourceCorruptError(RuntimeError):
    """A shard's durable vector source failed integrity checks."""


class RepairError(RuntimeError):
    """A rebuilt shard failed verification (audit or spot-check)."""


# ---------------------------------------------------------------------------
# Durable per-shard vector source
# ---------------------------------------------------------------------------

class ShardVectorStore:
    """CRC-verified per-shard vector snapshots backing shard rebuilds.

    Layout under ``directory``::

        meta.json           {n_shards, n_total, per, dim, seed, quantized,
                             params}  — written once at create
        shard_XXXX.npz      the shard's full padded rows (``shard_rows``
                            output — rebuild input is bit-identical to the
                            original build input)
        shard_XXXX.json     {shard, n_real, dtype, shape, crc}
    """

    def __init__(self, directory: str):
        self.directory = directory
        with open(os.path.join(directory, "meta.json")) as f:
            self.meta = json.load(f)
        self.params = BuildParams(**self.meta["params"])

    @property
    def n_shards(self) -> int:
        return int(self.meta["n_shards"])

    @property
    def n_total(self) -> int:
        return int(self.meta["n_total"])

    @property
    def quantized(self) -> bool:
        return bool(self.meta["quantized"])

    @property
    def seed(self) -> int:
        return int(self.meta["seed"])

    @classmethod
    def create(cls, directory: str, vectors, n_shards: int,
               params: Optional[BuildParams] = None, quantized: bool = False,
               seed: int = 0) -> "ShardVectorStore":
        vectors = np.asarray(vectors, np.float32)
        n = vectors.shape[0]
        per = int(np.ceil(n / n_shards))
        os.makedirs(directory, exist_ok=True)
        for s in range(n_shards):
            rows, n_real = shard_rows(vectors, s, per)
            base = os.path.join(directory, f"shard_{s:04d}")
            import io
            buf = io.BytesIO()
            np.savez(buf, rows=rows)
            _atomic_write(base + ".npz", buf.getvalue())
            manifest = {
                "shard": s,
                "n_real": n_real,
                "dtype": str(rows.dtype),
                "shape": list(rows.shape),
                "crc": _crc(rows),
            }
            _atomic_write(base + ".json", json.dumps(manifest).encode())
        meta = {
            "n_shards": n_shards,
            "n_total": n,
            "per": per,
            "dim": int(vectors.shape[1]),
            "seed": seed,
            "quantized": quantized,
            "params": dataclasses.asdict(params or BuildParams()),
        }
        _atomic_write(os.path.join(directory, "meta.json"),
                      json.dumps(meta).encode())
        return cls(directory)

    def load_shard(self, shard: int) -> tuple[np.ndarray, int]:
        """Load + verify one shard's padded rows.  Returns ``(rows, n_real)``;
        raises ``ShardSourceCorruptError`` on any integrity violation."""
        base = os.path.join(self.directory, f"shard_{shard:04d}")
        try:
            with open(base + ".json") as f:
                manifest = json.load(f)
        except Exception as e:
            raise ShardSourceCorruptError(
                f"shard {shard}: unreadable manifest: {e}") from e
        try:
            with np.load(base + ".npz") as z:
                rows = z["rows"].copy()
        except Exception as e:
            raise ShardSourceCorruptError(
                f"shard {shard}: unreadable payload: {e}") from e
        if list(rows.shape) != manifest["shape"]:
            raise ShardSourceCorruptError(
                f"shard {shard}: shape mismatch "
                f"{list(rows.shape)} != {manifest['shape']}")
        if _crc(rows) != manifest["crc"]:
            raise ShardSourceCorruptError(f"shard {shard}: checksum mismatch")
        return rows, int(manifest["n_real"])

    def build_shard(self, shard: int):
        """From-source rebuild of one shard's index — bit-identical to the
        slot ``build_sharded`` originally produced."""
        rows, _ = self.load_shard(shard)
        return build_shard(rows, shard, self.params, self.quantized,
                           self.seed)


# ---------------------------------------------------------------------------
# Repair controller
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RepairConfig:
    budget_per_sweep: int = 1          # max repair attempts per sweep
    backoff_s: float = 0.5             # first-retry delay after a failure
    backoff_cap_s: float = 30.0        # exponential backoff ceiling
    audit_sample: int = 16             # verify.audit monotone-probe sample
    probe_queries: int = 4             # spot-check self-probes per repair
    probe_self_tol: float = 0.5        # min fraction of self-probes that hit


@dataclasses.dataclass(frozen=True)
class RepairOutcome:
    shard: int
    replica: int
    status: str                        # "succeeded" | "failed"
    attempt: int
    duration_s: float
    error: Optional[str] = None


def install_slot(sidx: ShardedIndex, slot: int, local) -> ShardedIndex:
    """New ``ShardedIndex`` with physical slot ``slot`` replaced by
    ``local`` (a single-shard index pytree).  Purely functional — the old
    index is untouched, so a crash mid-install can never corrupt serving."""
    index = jax.tree.map(lambda full, one: full.at[slot].set(one),
                         sidx.index, local)
    return dataclasses.replace(sidx, index=index)


class RepairController:
    """Sweeps dead replicas and repairs them (see module docstring).

    ``get_sidx`` / ``set_sidx`` decouple the controller from index
    ownership: the serve layer passes closures over its live
    ``ShardedIndex`` so an install atomically swaps one consistent pytree.
    ``sweep`` is cheap when nothing is dead (one O(S·R) registry scan) —
    call it per dispatch, after the health check.
    """

    def __init__(self, store: ShardVectorStore,
                 registry: ShardHealthRegistry,
                 get_sidx: Callable[[], ShardedIndex],
                 set_sidx: Callable[[ShardedIndex], None],
                 config: Optional[RepairConfig] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 probe_params: Optional[SearchParams] = None,
                 metrics=None,
                 fault_hook: Optional[Callable[[str], None]] = None):
        if store.n_shards != registry.n_shards:
            raise ValueError(f"store has {store.n_shards} shards, registry "
                             f"{registry.n_shards}")
        self.store = store
        self.registry = registry
        self.get_sidx = get_sidx
        self.set_sidx = set_sidx
        self.config = config or RepairConfig()
        self.clock = clock
        self.probe_params = probe_params
        self.metrics = metrics
        self.fault_hook = fault_hook
        self._attempts: dict[tuple[int, int], int] = {}
        self._next_try: dict[tuple[int, int], float] = {}
        self.n_sweeps = 0
        self.n_repaired = 0
        self.n_failed = 0

    # -- helpers -------------------------------------------------------------
    def _fault(self, point: str) -> None:
        if self.fault_hook is not None:
            self.fault_hook(point)

    def _event(self, name: str, **kw) -> None:
        # registry.event auto-increments the matching ``{name}_total``
        # counter, so the taxonomy's repair_* counters ride the events
        if self.metrics is not None:
            self.metrics.event(name, **kw)

    def _under_repair(self, shard: int, val: float) -> None:
        if self.metrics is not None:
            self.metrics.gauge("shard_under_repair", {"shard": shard}).set(val)

    # -- scheduling ----------------------------------------------------------
    def pending(self) -> list[tuple[int, int]]:
        """Dead (shard, replica) slots, coverage holes first: a shard with
        NO live replica is a correctness gap (results are missing rows), a
        dead replica of a covered shard only costs redundancy."""
        reg = self.registry
        dead = [(s, r) for s in range(reg.n_shards)
                for r in range(reg.n_replicas) if not reg._live[s, r]]
        return sorted(dead, key=lambda sr: (bool(reg._live[sr[0]].any()),
                                            sr[0], sr[1]))

    def sweep(self, now: Optional[float] = None) -> list[RepairOutcome]:
        """One repair sweep: attempt up to ``budget_per_sweep`` repairs on
        dead slots whose backoff window has passed."""
        now = self.clock() if now is None else now
        self.n_sweeps += 1
        budget = self.config.budget_per_sweep
        outcomes: list[RepairOutcome] = []
        for s, r in self.pending():
            if budget <= 0:
                break
            if self._next_try.get((s, r), -np.inf) > now:
                continue    # still backing off
            budget -= 1
            outcomes.append(self._repair(s, r, now))
        return outcomes

    # -- one repair ----------------------------------------------------------
    def _repair(self, s: int, r: int, now: float) -> RepairOutcome:
        attempt = self._attempts.get((s, r), 0) + 1
        self._attempts[(s, r)] = attempt
        self._under_repair(s, 1.0)
        self._event("repair_started", shard=s, replica=r, attempt=attempt)
        t0 = self.clock()

        # contained phase: any failure here leaves serving untouched
        try:
            self._fault("load_source")
            rows, n_real = self.store.load_shard(s)
            self._fault("rebuild")
            local = build_shard(rows, s, self.store.params,
                                self.store.quantized, self.store.seed)
            self._verify(local, s)
            slot = s * self.registry.n_replicas + r
            candidate = install_slot(self.get_sidx(), slot, local)
            self._spot_check(candidate, slot, rows, n_real)
        except Exception as e:  # noqa: BLE001 — contained by design
            self.n_failed += 1
            delay = min(self.config.backoff_s * 2.0 ** (attempt - 1),
                        self.config.backoff_cap_s)
            self._next_try[(s, r)] = now + delay
            self._event("repair_failed", shard=s, replica=r, attempt=attempt,
                        error=f"{type(e).__name__}: {e}", retry_in_s=delay)
            return RepairOutcome(shard=s, replica=r, status="failed",
                                 attempt=attempt,
                                 duration_s=self.clock() - t0,
                                 error=f"{type(e).__name__}: {e}")

        # install phase: NOT contained — a raising fault hook here simulates
        # a crash; the mask flips only after the verified install lands
        self._fault("before_install")
        self.set_sidx(candidate)
        self._fault("mid_install")
        self.registry.mark_live(s, r)
        self._fault("after_install")

        dur = self.clock() - t0
        self.n_repaired += 1
        self._attempts.pop((s, r), None)
        self._next_try.pop((s, r), None)
        self._under_repair(s, 0.0)
        if self.metrics is not None:
            self.metrics.histogram("repair_duration_seconds").observe(dur)
        self._event("repair_succeeded", shard=s, replica=r, attempt=attempt,
                    duration_s=dur)
        return RepairOutcome(shard=s, replica=r, status="succeeded",
                             attempt=attempt, duration_s=dur)

    # -- verification --------------------------------------------------------
    def _verify(self, local, shard: int) -> None:
        graph = local.graph if isinstance(local, EMQGIndex) else local
        report = audit(graph, sample=self.config.audit_sample, seed=0)
        if not report.ok:
            raise RepairError(
                f"shard {shard}: rebuilt graph failed audit: "
                f"{report.violations}")

    def _spot_check(self, candidate: ShardedIndex, slot: int,
                    rows: np.ndarray, n_real: int) -> None:
        """host_reference_merge restricted to the candidate slot: returned
        ids must be valid global ids, and self-probes (queries that ARE
        stored rows) must find their own row at distance ~0."""
        if n_real <= 0:
            return                          # a rowless slot serves nothing
        reg = ShardHealthRegistry(self.registry.n_shards,
                                  self.registry.n_replicas,
                                  clock=self.clock)
        reg._live[:] = False
        reg._live[slot // reg.n_replicas, slot % reg.n_replicas] = True
        m = min(self.config.probe_queries, n_real)
        queries = rows[:m]
        params = self.probe_params or SearchParams(k=1, l0=16, l_max=32,
                                                   adaptive=False)
        ids, dists = host_reference_merge(candidate, reg, queries, params,
                                          quantized=self.store.quantized)
        ids, dists = np.asarray(ids), np.asarray(dists)
        valid = ids >= 0
        if (ids[valid] >= candidate.n_total).any():
            raise RepairError(
                f"slot {slot}: spot-check leaked a global id >= "
                f"{candidate.n_total}")
        if not np.isfinite(dists[valid]).all():
            raise RepairError(f"slot {slot}: non-finite distance on a "
                              "returned id")
        offset = int(np.asarray(candidate.offsets)[slot])
        expect = offset + np.arange(m)      # probes are the shard's own rows
        hit = (ids[:, 0] == expect) & (dists[:, 0] <= 1e-4)
        if hit.mean() < self.config.probe_self_tol:
            raise RepairError(
                f"slot {slot}: only {int(hit.sum())}/{m} self-probes found "
                "their own row")
