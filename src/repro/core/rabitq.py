"""RaBitQ 1-bit-per-dimension quantization (Gao & Long, SIGMOD'24) — the
distance-estimation substrate of δ-EMQG (Sec. 6 of the paper).

Scheme
------
With centroid ``c`` and a random orthogonal rotation ``P``:

    r   = P(v − c)             rotated residual
    b   = sign bits of r       (packed 32 dims / uint32)
    o   = r / ‖r‖              unit residual direction
    x̄   = sign(r) / √d         unit quantized direction
    ip_xo = ⟨x̄, o⟩ = Σ|rᵢ| / (√d·‖r‖)

For a query with rotated unit residual ``q_u`` and the identity
``⟨x̄, q_u⟩ = (2·S₊ − Σ q_u) / √d`` where ``S₊ = Σ_{bit=1} q_uᵢ``, the
(asymptotically unbiased) RaBitQ estimator is

    ⟨o, q_u⟩ ≈ ⟨x̄, q_u⟩ / ⟨x̄, o⟩
    d²(v,q) ≈ ‖v−c‖² + ‖q−c‖² − 2‖v−c‖‖q−c‖·⟨o, q_u⟩

TPU adaptation (recorded in DESIGN.md): the original FastScan evaluates
``S₊`` through AVX2 4-bit LUT shuffles; here ``S₊`` is an MXU contraction of
unpacked ±1 codes against the rotated query — the Pallas kernel in
``repro.kernels.bitdot`` does the unpack in VREGs; this module holds the
pure-jnp oracle and all scalar bookkeeping.  The query stays in f32 (the
paper quantizes it to 4-bit for SIMD; on TPU that step buys nothing).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .types import RaBitQCodes, take_rows


def random_rotation(dim: int, key: jax.Array) -> jax.Array:
    """Haar-ish random orthogonal matrix via QR of a Gaussian."""
    g = jax.random.normal(key, (dim, dim), jnp.float32)
    qmat, r = jnp.linalg.qr(g)
    # fix signs so the distribution is rotation-invariant
    return qmat * jnp.sign(jnp.diagonal(r))[None, :]


def pack_bits(bits: jax.Array) -> jax.Array:
    """bool[n, d] → uint32[n, ceil(d/32)] (bit j of word w = dim 32w+j)."""
    n, d = bits.shape
    words = (d + 31) // 32
    pad = words * 32 - d
    b = jnp.pad(bits.astype(jnp.uint32), ((0, 0), (0, pad)))
    b = b.reshape(n, words, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))[None, None, :]
    return jnp.sum(b * weights, axis=-1, dtype=jnp.uint32)


def unpack_bits(codes: jax.Array, dim: int) -> jax.Array:
    """uint32[n, W] → f32[n, dim] of ±1 signs."""
    n, W = codes.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bits = (codes[:, :, None] >> shifts) & jnp.uint32(1)
    signs = 2.0 * bits.astype(jnp.float32) - 1.0
    return signs.reshape(n, W * 32)[:, :dim]


@partial(jax.jit, static_argnames=("dim",))
def _fit_jit(vectors: jax.Array, rotation: jax.Array, dim: int):
    center = jnp.mean(vectors, axis=0)
    r = (vectors - center[None, :]) @ rotation.T
    norms = jnp.linalg.norm(r, axis=-1)
    codes = pack_bits(r > 0)
    ip_xo = jnp.sum(jnp.abs(r), axis=-1) / (
        jnp.sqrt(jnp.float32(dim)) * jnp.maximum(norms, 1e-30)
    )
    return codes, norms, ip_xo, center


def fit(vectors: jax.Array, key: jax.Array) -> RaBitQCodes:
    vectors = jnp.asarray(vectors, jnp.float32)
    dim = vectors.shape[1]
    rotation = random_rotation(dim, key)
    codes, norms, ip_xo, center = _fit_jit(vectors, rotation, dim)
    return RaBitQCodes(codes=codes, norms=norms, ip_xo=ip_xo,
                       rotation=rotation, center=center, dim=dim)


class QueryCtx(NamedTuple):
    """Per-query precomputation shared by every estimate during one search."""
    q: jax.Array        # f32[d]   the raw query (for exact probes)
    q_unit: jax.Array   # f32[d]   rotated unit residual direction
    sum_q: jax.Array    # f32[]    Σ q_unit
    norm_q: jax.Array   # f32[]    ‖q − c‖


def prepare_query(codes: RaBitQCodes, q: jax.Array) -> QueryCtx:
    r = (q - codes.center) @ codes.rotation.T
    norm_q = jnp.linalg.norm(r)
    q_unit = r / jnp.maximum(norm_q, 1e-30)
    return QueryCtx(q=q, q_unit=q_unit, sum_q=jnp.sum(q_unit), norm_q=norm_q)


def estimate_sqdist(codes: RaBitQCodes, ctx: QueryCtx, ids: jax.Array,
                    bitdot_fn=None) -> jax.Array:
    """Estimated squared distances f32[m] for node ids (INVALID → +inf).

    ``bitdot_fn(code_rows uint32[m,W], q_unit f32[d]) → S₊ f32[m]`` defaults
    to the pure-jnp oracle; the Pallas kernel is injected by the serving
    layer (repro.kernels.bitdot.ops.bitdot).
    """
    rows = take_rows(codes.codes, ids)
    if bitdot_fn is None:
        signs = unpack_bits(rows, codes.dim)            # ±1
        s_plus = 0.5 * (signs @ ctx.q_unit + ctx.sum_q)  # Σ_{bit=1} q_u
    else:
        s_plus = bitdot_fn(rows, ctx.q_unit)
    d = jnp.float32(codes.dim)
    ip_xq = (2.0 * s_plus - ctx.sum_q) / jnp.sqrt(d)
    ip_xo = jnp.maximum(take_rows(codes.ip_xo[:, None], ids)[:, 0], 1e-6)
    est_cos = ip_xq / ip_xo
    nv = take_rows(codes.norms[:, None], ids)[:, 0]
    d2 = nv * nv + ctx.norm_q * ctx.norm_q - 2.0 * nv * ctx.norm_q * est_cos
    d2 = jnp.maximum(d2, 0.0)
    return jnp.where(ids >= 0, d2, jnp.inf)


def estimator_error_bound(codes: RaBitQCodes, ids: jax.Array,
                          eps0: float = 1.9) -> jax.Array:
    """Per-vector high-probability bound on |⟨o,q⟩ − est| (RaBitQ Thm 3.2):
    ε ≈ ε₀·√((1 − ip_xo²) / ip_xo²) / √(d − 1).  ε₀≈1.9 ⇒ ~99.9% confidence."""
    ip = jnp.maximum(take_rows(codes.ip_xo[:, None], ids)[:, 0], 1e-6)
    d = jnp.float32(codes.dim)
    return eps0 * jnp.sqrt(jnp.maximum(1.0 - ip * ip, 0.0) / (ip * ip)) / jnp.sqrt(d - 1.0)


def exact_sqdist(vectors: jax.Array, q: jax.Array, ids: jax.Array) -> jax.Array:
    rows = take_rows(vectors, ids)
    d2 = jnp.sum((rows - q[None, :]) ** 2, axis=-1)
    return jnp.where(ids >= 0, d2, jnp.inf)
