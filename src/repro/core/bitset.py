"""Packed uint32 visited bitsets for lock-step graph traversal.

The seed's per-query engines deduped against a ``max_hops``-wide ring buffer
of expanded ids — every neighbor was broadcast-compared against the whole
ring, an O(M·T) wall per hop (T = 2048 for the adaptive engines).  A packed
bitset over the node-id space makes membership O(1) per neighbor and costs
``ceil(n/32)·4`` bytes per query: 125 KiB for SIFT1M, which for a 64-query
batch is 8 MiB of HBM — noise next to the vectors themselves.

Layout: bit ``j`` of word ``w`` in row ``b`` ⇔ node ``32·w + j`` seen by
query ``b``.  All helpers take fixed-shape ``int32`` id arrays padded with
``INVALID_ID`` (negative); invalid slots never test positive and never set
or clear a bit, so the helpers compose with the masked lock-step state
machines without extra branching.

``bitset_clear`` is the inverse of ``bitset_set`` and exists for the literal
Algorithm-3 prune (``faithful_prune=True``): a candidate pruned out of the
top-(l+1) window before it was ever expanded must be able to *re-enter* the
search once ``l`` grows, so its visited bit is cleared when it is pruned.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_WORD_BITS = 32


def bitset_words(n: int) -> int:
    """Number of uint32 words needed to cover ``n`` node ids."""
    return (n + _WORD_BITS - 1) // _WORD_BITS


def bitset_make(batch: int, n: int) -> jax.Array:
    """Empty bitset ``uint32[batch, ceil(n/32)]``."""
    return jnp.zeros((batch, bitset_words(n)), jnp.uint32)


def bitset_test(bits: jax.Array, ids: jax.Array) -> jax.Array:
    """Membership test.  bits uint32[B, nw], ids int32[B, K] → bool[B, K].

    Invalid (negative) ids test False.
    """
    safe = jnp.maximum(ids, 0)
    word = safe >> 5
    bit = (safe & 31).astype(jnp.uint32)
    rows = jnp.take_along_axis(bits, word, axis=1)
    hit = ((rows >> bit) & jnp.uint32(1)) != 0
    return hit & (ids >= 0)


def bitset_set(bits: jax.Array, ids: jax.Array) -> jax.Array:
    """Set the bits for ``ids`` (must be unique per row among valid entries).

    Uses a scatter-add of one-bit masks: with unique (word, bit) pairs per
    row, addition is exactly bitwise-or and never carries.  Invalid ids are
    routed out of bounds and dropped by the scatter.
    """
    nw = bits.shape[1]
    word = jnp.where(ids >= 0, ids >> 5, nw)        # invalid → OOB, dropped
    mask = jnp.where(
        ids >= 0, jnp.uint32(1) << (ids & 31).astype(jnp.uint32), jnp.uint32(0)
    )
    delta = jnp.zeros_like(bits)
    rows = jnp.arange(bits.shape[0], dtype=jnp.int32)[:, None]
    delta = delta.at[rows, word].add(mask, mode="drop")
    return bits | delta


def bitset_clear(bits: jax.Array, ids: jax.Array) -> jax.Array:
    """Clear the bits for ``ids`` (must be unique per row among valid entries).

    Exact inverse of ``bitset_set`` under the same uniqueness precondition:
    the scatter-add accumulates one-bit masks that never carry, and the
    result is and-not-ed out of ``bits``.  Invalid (negative) ids are routed
    out of bounds and dropped; clearing a bit that was never set is a no-op.
    """
    nw = bits.shape[1]
    word = jnp.where(ids >= 0, ids >> 5, nw)        # invalid → OOB, dropped
    mask = jnp.where(
        ids >= 0, jnp.uint32(1) << (ids & 31).astype(jnp.uint32), jnp.uint32(0)
    )
    delta = jnp.zeros_like(bits)
    rows = jnp.arange(bits.shape[0], dtype=jnp.int32)[:, None]
    delta = delta.at[rows, word].add(mask, mode="drop")
    return bits & ~delta


def unique_per_row(ids: jax.Array, fresh: jax.Array) -> jax.Array:
    """Compact ``ids`` to its per-row unique valid entries.

    ids int32[B, K], fresh bool[B, K] → int32[B, K] sorted ascending with
    duplicates and non-fresh entries replaced by INVALID_ID (pushed to the
    tail as far as the valid prefix is concerned).  This is the intra-hop
    dedup for beam expansion: the W frontier nodes of one query may share
    neighbors, and each unique id must be evaluated (and bitset-marked)
    exactly once.
    """
    big = jnp.int32(2**30)
    sorted_ids = jnp.sort(jnp.where(fresh, ids, big), axis=1)
    first = jnp.concatenate(
        [
            jnp.ones(sorted_ids.shape[:1] + (1,), jnp.bool_),
            sorted_ids[:, 1:] != sorted_ids[:, :-1],
        ],
        axis=1,
    )
    keep = first & (sorted_ids < big)
    return jnp.where(keep, sorted_ids, jnp.int32(-1))
