"""MIPS → L2 reduction for inner-product retrieval over a δ-EMG.

The recsys retrieval head maximizes ⟨u, v⟩ while the δ-EMG index answers
min-L2 queries.  The standard exact reduction (Bachrach et al. 2014)
augments items with one extra coordinate:

    φ(v) = [v, √(R² − ‖v‖²)]      R = max‖v‖   (items)
    ψ(u) = [u, 0]                                (queries)

    ‖ψ(u) − φ(v)‖² = ‖u‖² + R² − 2⟨u, v⟩  →  argmin L2 ≡ argmax IP

so a δ-EMG built over φ(items) serves exact-equivalent MIPS, and the
(1/δ′) L2 certificate translates to an additive inner-product bound:
⟨u, v̂⟩ ≥ ⟨u, v*⟩ − (1/δ′² − 1)·d²(ψ(u), φ(v*))/2.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .build_approx import BuildParams, build_approx
from .emqg import build_emqg
from .search import error_bounded_search
from .types import EMQGIndex, GraphIndex, SearchResult


@dataclasses.dataclass
class MIPSIndex:
    index: GraphIndex | EMQGIndex
    radius: float                 # R = max ‖v‖
    dim: int                      # original dimensionality

    @property
    def quantized(self) -> bool:
        return isinstance(self.index, EMQGIndex)


def augment_items(items: np.ndarray) -> tuple[np.ndarray, float]:
    items = np.asarray(items, np.float32)
    norms2 = (items ** 2).sum(-1)
    R2 = float(norms2.max())
    extra = np.sqrt(np.maximum(R2 - norms2, 0.0))[:, None]
    return np.concatenate([items, extra], axis=1), float(np.sqrt(R2))


def augment_queries(queries: np.ndarray) -> np.ndarray:
    queries = np.asarray(queries, np.float32)
    return np.concatenate(
        [queries, np.zeros((queries.shape[0], 1), np.float32)], axis=1)


def build_mips(items: np.ndarray, params: Optional[BuildParams] = None,
               quantized: bool = False) -> MIPSIndex:
    aug, R = augment_items(items)
    params = params or BuildParams()
    idx = build_emqg(aug, params) if quantized else build_approx(aug, params)
    return MIPSIndex(index=idx, radius=R, dim=items.shape[1])


def mips_search(mips: MIPSIndex, queries: np.ndarray, k: int,
                alpha: float = 1.2, l_max: int = 256) -> SearchResult:
    """Top-k by inner product (ids are item rows; dists are the reduced-L2
    distances — convert with ``ip_from_l2`` if scores are needed)."""
    aug_q = jnp.asarray(augment_queries(queries))
    if mips.quantized:
        from .probing import error_bounded_probing_search

        return error_bounded_probing_search(mips.index, aug_q, k=k,
                                            alpha=alpha, l_max=l_max)
    return error_bounded_search(mips.index, aug_q, k=k, alpha=alpha,
                                l_max=l_max)


def ip_from_l2(queries: np.ndarray, l2_dists, radius: float):
    """⟨u, v⟩ = (‖u‖² + R² − d²)/2 — recover scores from reduced distances."""
    q2 = (np.asarray(queries, np.float32) ** 2).sum(-1, keepdims=True)
    d2 = np.asarray(l2_dists) ** 2
    return (q2 + radius ** 2 - d2) / 2.0
