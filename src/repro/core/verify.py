"""Graph-invariant auditor for δ-EMG indexes (post-recovery / post-mutation).

The paper's approximation guarantee rests on structural invariants — not
just connectivity but δ-monotonicity (Zhu & Zhang 2021: monotonicity is the
load-bearing property; a connected-but-non-monotonic graph loses the
``1/δ`` bound).  Streaming mutation (``core.updates``) and crash recovery
(WAL replay) restore those invariants *locally*; this module checks them
globally so a recovered or heavily-mutated index can be certified before it
re-enters serving:

* **structure**   — ids in range, no self-loops, no duplicate edges per row
                    (hard errors: these mean corrupted adjacency).
* **degree**      — every row within the cap; no isolated live node.
* **tombstones**  — bitmap shape/dtype matches the graph; a live medoid
                    (traversal entry point must not be deleted-but-routed).
* **reachability** — BFS from the medoid covers every live node (a node
                    unreachable by *any* path can never be returned).
* **monotone descent (sampled)** — for a sample of live nodes ``u``, greedy
  search with query ``vec(u)`` must reach ``u`` itself: on a δ-monotonic
  graph every query has a monotone path from the entry point to its exact
  nearest neighbor, and ``u`` is its own vector's exact NN (distance 0).
  Checked with the production beam engine at a small fixed window.  An
  *approximately*-built graph (Alg. 4) only approximates the closure, so
  isolated probe misses are warnings; a failure fraction above
  ``monotone_tol`` is a hard violation — that is a structural routing
  defect, not a construction artifact.
* **reverse-edge symmetry under the cap** — for each edge (u, v) with
  ``deg(v) < M`` and v not tombstoned, (v, u) should usually exist (the
  build and insert paths both add reverse edges while there is room).
  Occlusion pruning may legitimately drop some, so this is reported as a
  *metric* with a configurable tolerance, not a hard error.

``audit`` returns an ``AuditReport``; ``report.ok`` is True iff no hard
violation was found.  Runnable from the CLI via ``launch/serve.py --audit``
and invoked by the fault-injection suite after every recovery/consolidate.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .search import SearchParams, search
from .types import GraphIndex


@dataclasses.dataclass
class AuditReport:
    """Outcome of one invariant audit."""

    n: int = 0
    n_live: int = 0
    violations: list = dataclasses.field(default_factory=list)   # hard errors
    warnings: list = dataclasses.field(default_factory=list)     # soft findings
    metrics: dict = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        head = ("OK" if self.ok else f"{len(self.violations)} VIOLATION(S)")
        lines = [f"[audit] {head} — n={self.n} live={self.n_live}"]
        lines += [f"  ERROR: {v}" for v in self.violations]
        lines += [f"  warn:  {w}" for w in self.warnings]
        for k in sorted(self.metrics):
            lines.append(f"  {k} = {self.metrics[k]}")
        return "\n".join(lines)


def _bfs_live_reachable(nbr: np.ndarray, start: int) -> np.ndarray:
    """bool[n]: reachable from ``start`` (tombstones route, so no filtering)."""
    n = nbr.shape[0]
    seen = np.zeros(n, bool)
    seen[start] = True
    frontier = np.array([start])
    while frontier.size:
        nxt = nbr[frontier].ravel()
        nxt = nxt[nxt >= 0]
        nxt = np.unique(nxt)
        nxt = nxt[~seen[nxt]]
        seen[nxt] = True
        frontier = nxt
    return seen


def audit(graph: GraphIndex, tombstones: np.ndarray | None = None,
          sample: int = 32, seed: int = 0,
          symmetry_tol: float = 0.25,
          check_monotone: bool = True,
          monotone_tol: float = 0.1) -> AuditReport:
    """Audit the invariants listed in the module docstring.

    ``tombstones`` — optional bool[n] (a plain ``GraphIndex`` audit passes
    None → all nodes live).  ``sample`` caps the number of monotone-descent
    probes.  ``symmetry_tol`` is the tolerated fraction of missing reverse
    edges among edges whose target has spare capacity; ``monotone_tol`` the
    tolerated fraction of failed descent probes (see module docstring).
    """
    nbr = np.asarray(graph.neighbors)
    n, M = nbr.shape
    rep = AuditReport(n=n)
    tomb = (np.zeros(n, bool) if tombstones is None
            else np.asarray(tombstones))

    # -- tombstone bitmap consistency ---------------------------------------
    if tomb.shape != (n,):
        rep.violations.append(
            f"tombstone bitmap shape {tomb.shape} != ({n},)")
        tomb = np.zeros(n, bool)
    if tomb.dtype != np.bool_:
        rep.violations.append(f"tombstone bitmap dtype {tomb.dtype} != bool")
        tomb = tomb.astype(bool)
    live = ~tomb
    rep.n_live = int(live.sum())
    med = int(np.asarray(graph.medoid))
    if not (0 <= med < n):
        rep.violations.append(f"medoid {med} out of range [0, {n})")
        return rep        # nothing below is meaningful without an entry point
    if tomb[med]:
        rep.violations.append(f"medoid {med} is tombstoned")
    if rep.n_live == 0:
        rep.violations.append("no live nodes")
        return rep

    # -- structure ----------------------------------------------------------
    n_oob = int(((nbr < -1) | (nbr >= n)).sum())
    if n_oob:
        rep.violations.append(f"{n_oob} neighbor ids out of range [-1, {n})")
    self_loops = int((nbr == np.arange(n)[:, None]).sum())
    if self_loops:
        rep.violations.append(f"{self_loops} self-loop edges")
    # duplicate neighbors within a row (among valid entries)
    srt = np.sort(np.where(nbr >= 0, nbr, -np.arange(1, n * M + 1)
                           .reshape(n, M)), axis=1)
    n_dup = int(((srt[:, 1:] == srt[:, :-1]) & (srt[:, 1:] >= 0)).sum())
    if n_dup:
        rep.violations.append(f"{n_dup} duplicate edges within rows")

    # -- degree -------------------------------------------------------------
    deg = (nbr >= 0).sum(1)
    rep.metrics["mean_degree"] = float(deg[live].mean())
    rep.metrics["max_degree_cap"] = M
    isolated = np.where(live & (deg == 0) & (np.arange(n) != med))[0]
    if isolated.size and rep.n_live > 1:
        rep.violations.append(
            f"{isolated.size} isolated live nodes (first: "
            f"{isolated[:5].tolist()})")

    if n_oob:
        return rep        # BFS / gather below would index out of bounds

    # -- reachability (every live node, exact BFS) --------------------------
    seen = _bfs_live_reachable(nbr, med)
    unreachable = np.where(live & ~seen)[0]
    rep.metrics["n_unreachable_live"] = int(unreachable.size)
    if unreachable.size:
        rep.violations.append(
            f"{unreachable.size} live nodes unreachable from medoid "
            f"(first: {unreachable[:5].tolist()})")

    # -- reverse-edge symmetry under the cap --------------------------------
    edge_set = set()
    for u in range(n):
        for v in nbr[u]:
            if v >= 0:
                edge_set.add((u, int(v)))
    considered = missing = 0
    for (u, v) in edge_set:
        if deg[v] >= M or tomb[v] or tomb[u]:
            continue          # cap-full or tombstoned targets are exempt
        considered += 1
        if (v, u) not in edge_set:
            missing += 1
    frac_missing = missing / max(considered, 1)
    rep.metrics["reverse_edge_missing_frac"] = float(frac_missing)
    if frac_missing > symmetry_tol:
        rep.warnings.append(
            f"reverse-edge symmetry-under-cap: {missing}/{considered} "
            f"({frac_missing:.2f}) missing > tol {symmetry_tol}")

    # -- sampled δ-monotone descent -----------------------------------------
    if check_monotone and unreachable.size == 0:
        rng = np.random.default_rng(seed)
        live_ids = np.where(seen & live)[0]
        probe = rng.choice(live_ids, size=min(sample, live_ids.size),
                           replace=False).astype(np.int32)
        vecs = np.asarray(graph.vectors)[probe]
        p = SearchParams(k=1, l0=8, l_max=64, alpha=1.2, adaptive=True,
                         max_hops=2048)
        res = search(graph, jnp.asarray(vecs), p)
        got = np.asarray(res.ids)[:, 0]
        dists = np.asarray(res.dists)[:, 0]
        # success = reached the node itself, or an exact duplicate of it
        bad = np.where((got != probe) & (dists > 1e-5))[0]
        rep.metrics["monotone_probes"] = int(probe.size)
        rep.metrics["monotone_failures"] = int(bad.size)
        if bad.size:
            msg = (f"monotone descent failed for {bad.size}/{probe.size} "
                   f"sampled nodes (first: {probe[bad[:5]].tolist()})")
            if bad.size / probe.size > monotone_tol:
                rep.violations.append(msg)
            else:
                rep.warnings.append(msg)
    return rep


def audit_live(live, **kw) -> AuditReport:
    """Audit a ``core.updates.LiveIndex`` (graph + tombstone bitmap)."""
    return audit(live.graph, tombstones=live.tombstones, **kw)
