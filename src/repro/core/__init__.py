"""δ-EMG core — the paper's contribution as a composable JAX library.

Public API:
    Index containers:  GraphIndex, RaBitQCodes, EMQGIndex, ShardedIndex
    Construction:      build_exact (Alg. 2), build_approx (Alg. 4),
                       build_emqg (Sec. 6.1), baselines.BUILDERS
    Search:            greedy_search (Alg. 1), error_bounded_search (Alg. 3),
                       probing_search / error_bounded_probing_search (Alg. 5),
                       ags_search (ablation).  All route through the
                       batch-level beam engine (SearchParams.beam_width);
                       correctness is certified by implementation-independent
                       oracles (repro.testing.oracle: brute-force exact k-NN
                       plus the paper's (1/δ) bound), not a reference engine.
    Distribution:      build_sharded, build_replicated, make_sharded_search,
                       ShardHealthRegistry, FaultTolerantShardedSearch
    Maintenance:       updates.JournaledLiveIndex (WAL + crash recovery),
                       verify.audit (graph-invariant auditor),
                       repair.RepairController + repair.ShardVectorStore
                       (self-healing shard re-replication)
    Theory probes:     local_optimum_mask, theorem4_delta_prime
"""

from .types import (  # noqa: F401
    EMQGIndex,
    GraphIndex,
    INVALID_ID,
    RaBitQCodes,
    SearchParams,
    SearchResult,
)
from .build_exact import build_exact  # noqa: F401
from .build_approx import BuildParams, build_approx  # noqa: F401
from .emqg import build_emqg, from_graph, memory_footprint  # noqa: F401
from .search import (  # noqa: F401
    error_bounded_search,
    greedy_search,
    local_optimum_mask,
    make_batch_dist_fn,
    search,
    theorem4_delta_prime,
)
from .probing import (  # noqa: F401
    ags_search,
    error_bounded_probing_search,
    probing_search,
)
from . import baselines, bitset, distances, distributed, geometry, rabitq  # noqa: F401
from . import filtered, mips, repair, updates, verify  # noqa: F401  (beyond-paper features)
