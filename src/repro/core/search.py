"""Batched, fixed-shape beam search on proximity graphs.

Implements Algorithm 1 (greedy beam search) and Algorithm 3 (error-bounded
adaptive top-k search) of the paper as a *single* parameterized engine,
reformulated for lock-step execution on TPU:

* The candidate set ``C`` is a fixed-width sorted array (ids, squared dists,
  visited flags) of capacity ``l_max + 1``.  Algorithm 3's literal "keep top
  l+1" prune is available as ``faithful_prune=True``, but read literally it
  deadlocks the adaptive loop: when ``l`` grows into a slot whose candidate
  was pruned away (or already visited), the stop test ``d(q,C[l]) ≥ α·d(q,C[k])``
  sees ``+inf`` and fires *regardless of α*, contradicting the paper's own
  Exp-6/7 (α must widen the search).  The default ``faithful_prune=False``
  retains the full ``l_max+1`` buffer — the window ``l`` still gates which
  candidates may be *expanded* and the stop rule still reads ``C[l]``/``C[k]``,
  which realizes the intended adaptive behavior (and is how NSG-style pools
  with a growing capacity behave).  Both variants are measured in
  EXPERIMENTS.md §Perf.
* The visited set ``T`` is a ring buffer of the expanded node ids (at most
  one per hop, so ``max_hops`` bounds it).  Membership tests are vectorized
  broadcast-compares — no hashing, no host round trips.
* Per-query adaptive state (current ``l``, done flags, distance counters)
  rides in the ``while_loop`` carry; ``vmap`` turns the per-query loop into a
  batched lock-step loop where finished queries are masked no-ops.

The distance evaluation is pluggable (``dist_fn``) so the δ-EMQG probing
search (``probing.py``) and the Pallas kernels (``repro.kernels``) can swap
in quantized / fused implementations without touching the control flow.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .types import (
    INVALID_ID,
    EMQGIndex,
    GraphIndex,
    SearchParams,
    SearchResult,
    take_rows,
)


class _State(NamedTuple):
    cand_ids: jax.Array    # int32[C]
    cand_d2: jax.Array     # f32[C]   squared dists, ascending (inf = empty)
    cand_vis: jax.Array    # bool[C]
    t_ids: jax.Array       # int32[T] expanded-node ring buffer
    t_cnt: jax.Array       # int32
    l: jax.Array           # int32    current candidate window (Alg. 3)
    n_dist: jax.Array      # int32    exact distance evaluations
    n_hops: jax.Array      # int32    expansions
    done: jax.Array        # bool
    saturated: jax.Array   # bool     l hit l_max before the α-rule fired


def make_exact_dist_fn(vectors: jax.Array) -> Callable:
    """dist_fn(q, ids) → squared distances f32[M] (invalid ids → +inf)."""

    def dist_fn(q, ids):
        rows = take_rows(vectors, ids)
        diff = rows.astype(jnp.float32) - q.astype(jnp.float32)[None, :]
        d2 = jnp.sum(diff * diff, axis=-1)
        return jnp.where(ids >= 0, d2, jnp.inf)

    return dist_fn


def _merge_topc(ids_a, d2_a, vis_a, ids_b, d2_b, vis_b, cap: int):
    """Merge two (id, d2, visited) lists, keep the ``cap`` smallest by d2."""
    ids = jnp.concatenate([ids_a, ids_b])
    d2 = jnp.concatenate([d2_a, d2_b])
    vis = jnp.concatenate([vis_a, vis_b])
    neg, idx = jax.lax.top_k(-d2, cap)
    return ids[idx], -neg, vis[idx]


def _search_one(
    neighbors: jax.Array,       # int32[n, M]
    dist_fn: Callable,
    q: jax.Array,               # f32[d]
    start: jax.Array,           # int32[]
    p: SearchParams,
    faithful_prune: bool,
) -> tuple[_State, jax.Array]:
    C = p.l_max + 1
    M = neighbors.shape[1]
    T = p.max_hops

    d2_start = dist_fn(q, start[None])[0]
    st = _State(
        cand_ids=jnp.full((C,), INVALID_ID, jnp.int32).at[0].set(start),
        cand_d2=jnp.full((C,), jnp.inf, jnp.float32).at[0].set(d2_start),
        cand_vis=jnp.zeros((C,), jnp.bool_),
        t_ids=jnp.full((T,), INVALID_ID, jnp.int32),
        t_cnt=jnp.int32(0),
        l=jnp.int32(min(max(p.l0, p.k), p.l_max)),
        n_dist=jnp.int32(1),
        n_hops=jnp.int32(0),
        done=jnp.bool_(False),
        saturated=jnp.bool_(False),
    )

    pos = jnp.arange(C, dtype=jnp.int32)
    alpha2 = jnp.float32(p.alpha * p.alpha)

    def in_window_unvisited(s: _State):
        return (pos < s.l) & (s.cand_ids >= 0) & (~s.cand_vis)

    def cond(s: _State):
        return (~s.done) & (s.n_hops < p.max_hops)

    def expand(s: _State) -> _State:
        mask = in_window_unvisited(s)
        sel = jnp.argmin(jnp.where(mask, s.cand_d2, jnp.inf))
        u_id = s.cand_ids[sel]
        cand_vis = s.cand_vis.at[sel].set(True)
        t_ids = s.t_ids.at[s.t_cnt % T].set(u_id)
        t_cnt = s.t_cnt + 1

        nbrs = jnp.take(neighbors, jnp.maximum(u_id, 0), axis=0)
        valid = nbrs >= 0
        in_cand = jnp.any(nbrs[:, None] == s.cand_ids[None, :], axis=1)
        in_vis = jnp.any(nbrs[:, None] == t_ids[None, :], axis=1)
        fresh = valid & ~in_cand & ~in_vis

        d2_new = dist_fn(q, jnp.where(fresh, nbrs, INVALID_ID))
        n_dist = s.n_dist + jnp.sum(fresh).astype(jnp.int32)

        cand_ids, cand_d2, cand_vis = _merge_topc(
            s.cand_ids, s.cand_d2, cand_vis,
            jnp.where(fresh, nbrs, INVALID_ID),
            jnp.where(fresh, d2_new, jnp.inf),
            jnp.zeros_like(fresh),
            C,
        )
        if faithful_prune:
            # Alg. 3 line 9: retain only the top l+1 candidates.
            keep = pos <= s.l
            cand_ids = jnp.where(keep, cand_ids, INVALID_ID)
            cand_d2 = jnp.where(keep, cand_d2, jnp.inf)
            cand_vis = jnp.where(keep, cand_vis, False)
        return s._replace(
            cand_ids=cand_ids, cand_d2=cand_d2, cand_vis=cand_vis,
            t_ids=t_ids, t_cnt=t_cnt, n_dist=n_dist, n_hops=s.n_hops + 1,
        )

    def converged(s: _State) -> _State:
        if not p.adaptive:
            return s._replace(done=jnp.bool_(True))
        # Alg. 3 line 11: stop iff d(q, C[l]) ≥ α · d(q, C[k]).
        d2_l = s.cand_d2[jnp.minimum(s.l - 1, C - 1)]
        d2_k = s.cand_d2[p.k - 1]
        stop = d2_l >= alpha2 * d2_k
        at_cap = s.l >= p.l_max
        new_l = jnp.minimum(s.l + p.l_step, p.l_max)
        return s._replace(
            l=jnp.where(stop, s.l, new_l),
            done=stop | at_cap,
            saturated=s.saturated | (at_cap & ~stop),
        )

    def body(s: _State) -> _State:
        has_unvisited = jnp.any(in_window_unvisited(s))
        return jax.lax.cond(has_unvisited, expand, converged, s)

    final = jax.lax.while_loop(cond, body, st)
    return final, q


@partial(jax.jit, static_argnames=("params", "faithful_prune", "with_candidates"))
def search(
    graph: GraphIndex,
    queries: jax.Array,                 # f32[B, d]
    params: SearchParams,
    start: Optional[jax.Array] = None,  # int32[B] or None → medoid
    faithful_prune: bool = False,
    with_candidates: bool = False,
):
    """Batched Alg. 1 / Alg. 3 search.  Returns SearchResult (and optionally
    the final candidate buffers for local-optimum analysis)."""
    B = queries.shape[0]
    if start is None:
        start = jnp.broadcast_to(graph.medoid, (B,)).astype(jnp.int32)
    dist_fn = make_exact_dist_fn(graph.vectors)

    def one(q, s0):
        st, _ = _search_one(graph.neighbors, dist_fn, q, s0, params, faithful_prune)
        return st

    st = jax.vmap(one)(queries, start)
    k = params.k
    res = SearchResult(
        ids=st.cand_ids[:, :k],
        dists=jnp.sqrt(jnp.maximum(st.cand_d2[:, :k], 0.0)),
        n_dist_comps=st.n_dist,
        n_approx_comps=jnp.zeros_like(st.n_dist),
        n_hops=st.n_hops,
        final_l=st.l,
        saturated=st.saturated,
    )
    if with_candidates:
        return res, st.cand_ids, jnp.sqrt(jnp.maximum(st.cand_d2, 0.0))
    return res


def greedy_search(graph: GraphIndex, queries: jax.Array, k: int, l: int,
                  start: Optional[jax.Array] = None, max_hops: int = 512) -> SearchResult:
    """Algorithm 1 with fixed candidate width l (the ablation δ-EMG-GS)."""
    p = SearchParams(k=k, l0=l, l_max=l, adaptive=False, max_hops=max_hops)
    return search(graph, queries, p, start=start)


def error_bounded_search(graph: GraphIndex, queries: jax.Array, k: int,
                         alpha: float, l_max: int = 256, l_step: int = 1,
                         start: Optional[jax.Array] = None,
                         max_hops: int = 2048, **kw) -> SearchResult:
    """Algorithm 3: adaptive candidate width with the α stop rule."""
    p = SearchParams(k=k, l0=k, l_max=l_max, l_step=l_step, alpha=alpha,
                     adaptive=True, max_hops=max_hops)
    return search(graph, queries, p, start=start, **kw)


# ---------------------------------------------------------------------------
# Theorem-4 instrumentation (Exp-6 / Exp-7).
# ---------------------------------------------------------------------------

@jax.jit
def local_optimum_mask(graph: GraphIndex, queries: jax.Array, cand_ids: jax.Array):
    """bool[B, C]: candidate c is a local optimum w.r.t. its query
    (no out-neighbor of c is strictly closer to q than c)."""

    def one(q, ids):
        d2_c = jnp.where(
            ids >= 0,
            jnp.sum((take_rows(graph.vectors, ids) - q[None, :]) ** 2, axis=-1),
            jnp.inf,
        )

        def check(cid, d2c):
            nbrs = jnp.take(graph.neighbors, jnp.maximum(cid, 0), axis=0)
            rows = take_rows(graph.vectors, nbrs)
            d2n = jnp.sum((rows - q[None, :]) ** 2, axis=-1)
            d2n = jnp.where(nbrs >= 0, d2n, jnp.inf)
            return (cid >= 0) & jnp.all(d2n >= d2c)

        return jax.vmap(check)(ids, d2_c)

    return jax.vmap(one)(queries, cand_ids)


def theorem4_delta_prime(graph: GraphIndex, queries: jax.Array, cand_ids: jax.Array,
                         cand_dists: jax.Array, k: int, delta: float):
    """Per-query (found: bool, δ′: f32) per Theorem 4.

    δ′ = δ · d(q, u) / d(q, r_(k)) with u the *farthest* local-optimum node in
    the final candidate set outside the returned top-k (wider search ⇒ larger
    d(q,u) ⇒ tighter bound — Exp-7's observation).
    """
    is_opt = local_optimum_mask(graph, queries, cand_ids)
    pos = jnp.arange(cand_ids.shape[1])[None, :]
    outside = pos >= k
    eligible = is_opt & outside & (cand_ids >= 0) & jnp.isfinite(cand_dists)
    d_u = jnp.max(jnp.where(eligible, cand_dists, -jnp.inf), axis=1)
    found = jnp.any(eligible, axis=1)
    d_rk = cand_dists[:, k - 1]
    delta_prime = jnp.where(found, delta * d_u / jnp.maximum(d_rk, 1e-30), 0.0)
    return found, delta_prime
