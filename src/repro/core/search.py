"""Batched, fixed-shape beam search on proximity graphs.

Implements Algorithm 1 (greedy beam search) and Algorithm 3 (error-bounded
adaptive top-k search) of the paper as a *single* parameterized engine,
reformulated for lock-step execution on TPU.

``search`` is the **batch-level beam engine** — the only graph-search engine
in the repo.  One ``while_loop`` drives the whole query batch: each
iteration selects the ``beam_width`` (W) best unvisited in-window candidates
per query, gathers all ``B×W×M`` neighbor ids at once, dedups them against a
packed ``uint32`` visited bitset (O(1) test/set/clear — see ``bitset.py``),
and evaluates every fresh distance in a *single* fused gather+L2 call over
``[B, W·M]`` ids.  On TPU that call is the Pallas ``gather_l2_tiled`` kernel
— one big contraction per hop for the MXU instead of B tiny ones; on CPU it
lowers to the identical-math jnp path.  Queries that have exhausted their
window take the adaptive-α transition (grow ``l`` or stop) in the same
lock-step iteration; finished queries are masked no-ops.

Semantics:

* The candidate set ``C`` is a fixed-width sorted array (ids, squared dists,
  visited flags) of capacity ``l_max + 1``.  Algorithm 3's literal "keep top
  l+1" prune is available as ``faithful_prune=True``: the merged candidate
  list is truncated to the top ``l+1`` every hop, and a pruned candidate
  that was never expanded has its visited bit *cleared* so it can re-enter
  (and be re-evaluated) once ``l`` grows — the re-insertion the literal
  algorithm relies on.  Read literally the prune can deadlock the adaptive
  loop: when ``l`` grows into a slot whose candidate was pruned away (or
  already visited), the stop test ``d(q,C[l]) ≥ α·d(q,C[k])`` sees ``+inf``
  and fires *regardless of α*, contradicting the paper's own Exp-6/7 (α must
  widen the search).  The default ``faithful_prune=False`` retains the full
  ``l_max+1`` buffer — the window ``l`` still gates which candidates may be
  *expanded* and the stop rule still reads ``C[l]``/``C[k]``, which realizes
  the intended adaptive behavior (and is how NSG-style pools with a growing
  capacity behave).
* The α-stop rule fires only when a query's window holds no unvisited
  candidate, so widening the per-hop frontier (W > 1) never skips the stop
  test — it only reorders the expansion schedule, which monotonic-graph
  convergence tolerates (the closure "expand until the window is exhausted"
  reaches the same fixed point family).

Correctness is checked against implementation-independent oracles, not a
reference engine: brute-force exact k-NN plus the paper's ``(1/δ)``
approximation bound (``repro.testing.oracle``, ``tests/test_conformance.py``),
and W=1 determinism / backend self-parity golden tests
(``tests/test_beam_engine.py``).

The distance evaluation is pluggable: ``backend`` selects
("auto" | "jnp" | "kernel" | "kernel_tiled"), and ``_beam_search_batch``
takes any ``batch_dist`` callable so the δ-EMQG searches (``probing.py``)
can swap in quantized implementations without touching the control flow.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .bitset import (
    bitset_clear,
    bitset_make,
    bitset_set,
    bitset_test,
    unique_per_row,
)
from .types import (
    INVALID_ID,
    GraphIndex,
    SearchParams,
    SearchResult,
    take_rows,
)


def make_exact_dist_fn(vectors: jax.Array) -> Callable:
    """dist_fn(q, ids) → squared distances f32[M] (invalid ids → +inf)."""

    def dist_fn(q, ids):
        rows = take_rows(vectors, ids)
        diff = rows.astype(jnp.float32) - q.astype(jnp.float32)[None, :]
        d2 = jnp.sum(diff * diff, axis=-1)
        return jnp.where(ids >= 0, d2, jnp.inf)

    return dist_fn


def make_batch_dist_fn(vectors: jax.Array, backend: str = "auto") -> Callable:
    """batch_dist(queries f32[B, d], ids int32[B, K]) → d2 f32[B, K].

    Backends:
      * ``jnp``          — fused batch gather + reduce in plain XLA.
      * ``kernel``       — Pallas ``gather_l2`` (one row DMA per grid step).
      * ``kernel_tiled`` — Pallas ``gather_l2_tiled`` (multi-row DMA blocks).
      * ``auto``         — ``kernel_tiled`` on TPU, ``jnp`` elsewhere
                           (interpret-mode Pallas inside a hot loop would be
                           orders of magnitude slower than XLA on CPU).
    """
    if backend == "auto":
        backend = "kernel_tiled" if jax.default_backend() == "tpu" else "jnp"
    if backend == "jnp":

        def batch_dist(queries, ids):
            rows = take_rows(vectors, ids)                     # [B, K, d]
            diff = rows.astype(jnp.float32) - queries.astype(jnp.float32)[:, None, :]
            d2 = jnp.sum(diff * diff, axis=-1)
            return jnp.where(ids >= 0, d2, jnp.inf)

        return batch_dist
    if backend in ("kernel", "kernel_tiled"):
        from repro.kernels.l2dist import ops as l2ops  # lazy: optional dep

        fn = l2ops.gather_l2_tiled if backend == "kernel_tiled" else l2ops.gather_l2

        def batch_dist(queries, ids):
            return fn(vectors.astype(jnp.float32), ids,
                      queries.astype(jnp.float32))

        return batch_dist
    raise ValueError(f"unknown distance backend: {backend!r}")


def batch_merge_topc(ids_a, d2_a, vis_a, ids_b, d2_b, vis_b, cap: int):
    """Batched merge: [B, Ca] ⊎ [B, Cb] → top-``cap`` smallest d2 per row.

    ``lax.top_k`` is stable (lower index wins ties), so appending the new
    entries after the existing buffer preserves the buffer's order for
    no-op merges — which is what keeps masked queries frozen in lock-step.
    """
    ids = jnp.concatenate([ids_a, ids_b], axis=1)
    d2 = jnp.concatenate([d2_a, d2_b], axis=1)
    vis = jnp.concatenate([vis_a, vis_b], axis=1)
    neg, idx = jax.lax.top_k(-d2, cap)
    take = lambda x: jnp.take_along_axis(x, idx, axis=1)  # noqa: E731
    return take(ids), -neg, take(vis)


# ---------------------------------------------------------------------------
# Batch-level beam engine.
# ---------------------------------------------------------------------------


class _BeamState(NamedTuple):
    cand_ids: jax.Array    # int32[B, C]
    cand_d2: jax.Array     # f32[B, C]   squared dists, ascending (inf = empty)
    cand_vis: jax.Array    # bool[B, C]
    seen: jax.Array        # uint32[B, nw] packed visited bitset
    l: jax.Array           # int32[B]    current candidate window (Alg. 3)
    n_dist: jax.Array      # int32[B]    exact distance evaluations
    n_enc: jax.Array       # int32[B]    candidate encounters (pre-dedup)
    n_hops: jax.Array      # int32[B]    expansions
    done: jax.Array        # bool[B]
    saturated: jax.Array   # bool[B]     l hit l_max before the α-rule fired


def select_top_w(d2: jax.Array, mask: jax.Array, w: int):
    """Per-row W best (smallest d2) slots among ``mask``.

    Returns (sel int32[B, W], valid bool[B, W]); ``lax.top_k`` stability
    gives W=1 a deterministic lowest-index tie-break (same as ``argmin``).
    """
    masked = jnp.where(mask, d2, jnp.inf)
    neg, sel = jax.lax.top_k(-masked, w)
    return sel, jnp.isfinite(neg)


def resolve_beam_width(p: SearchParams, cap: int) -> int:
    """Validate and clamp ``p.beam_width`` against the buffer capacity."""
    if p.beam_width < 1:
        raise ValueError(
            f"beam_width must be ≥ 1, got {p.beam_width} (0 would never "
            "expand a frontier and the lock-step loop could not terminate)")
    return min(p.beam_width, cap)   # can't select more than the buffer holds


def adaptive_transition(p: SearchParams, cand_d2: jax.Array, l: jax.Array,
                        done: jax.Array, saturated: jax.Array,
                        conv: jax.Array):
    """Alg.-3 line 11 lock-step transition for window-exhausted queries.

    Shared by the graph and probing beam engines so the stop rule can never
    desynchronize between them.  ``conv`` masks the queries taking the
    transition this iteration; others pass through unchanged.
    Returns (l, done, saturated).
    """
    if not p.adaptive:
        return l, done | conv, saturated
    C = cand_d2.shape[1]
    alpha2 = jnp.float32(p.alpha * p.alpha)
    # stop iff d(q, C[l]) ≥ α · d(q, C[k])
    d2_l = jnp.take_along_axis(
        cand_d2, jnp.minimum(l - 1, C - 1)[:, None], axis=1)[:, 0]
    d2_k = cand_d2[:, p.k - 1]
    stop = d2_l >= alpha2 * d2_k
    at_cap = l >= p.l_max
    new_l = jnp.minimum(l + p.l_step, p.l_max)
    return (
        jnp.where(conv & ~stop, new_l, l),
        done | (conv & (stop | at_cap)),
        saturated | (conv & at_cap & ~stop),
    )


def faithful_prune_merge(cand_ids, cand_d2, cand_vis, new_ids, d2_new,
                         seen, l, cap: int):
    """Literal Alg.-3 line-9 merge: full sort of buffer ∪ fresh, keep the top
    ``l+1`` per row, and *clear the visited bits* of pruned candidates that
    were never expanded so they can re-enter once ``l`` grows (the
    re-insertion the literal prune relies on; expanded nodes keep their bits
    — they play the role of the paper's visited set T).

    Returns (cand_ids, cand_d2, cand_vis, seen), buffers trimmed to ``cap``
    columns (safe: ``l+1 ≤ l_max+1 = cap`` bounds the kept prefix).
    """
    ids_all = jnp.concatenate([cand_ids, new_ids], axis=1)
    d2_all = jnp.concatenate([cand_d2, d2_new], axis=1)
    vis_all = jnp.concatenate(
        [cand_vis, jnp.zeros_like(new_ids, jnp.bool_)], axis=1)
    neg, order = jax.lax.top_k(-d2_all, ids_all.shape[1])      # full sort
    take = lambda x: jnp.take_along_axis(x, order, axis=1)  # noqa: E731
    ids_s, d2_s, vis_s = take(ids_all), -neg, take(vis_all)
    pos_all = jnp.arange(ids_s.shape[1], dtype=jnp.int32)[None, :]
    keep = pos_all <= l[:, None]
    # pruned ∧ unexpanded → clearable; ids are unique per row (buffer entries
    # are unique and fresh ids were, by definition, not in the buffer)
    clearable = jnp.where(keep | vis_s, INVALID_ID, ids_s)
    seen = bitset_clear(seen, clearable)
    return (jnp.where(keep, ids_s, INVALID_ID)[:, :cap],
            jnp.where(keep, d2_s, jnp.inf)[:, :cap],
            (keep & vis_s)[:, :cap],
            seen)


def _beam_search_batch(
    graph: GraphIndex,
    queries: jax.Array,        # f32[B, d]
    start: jax.Array,          # int32[B]
    p: SearchParams,
    batch_dist: Callable,
    faithful_prune: bool = False,
) -> _BeamState:
    B = queries.shape[0]
    C = p.l_max + 1
    W = resolve_beam_width(p, C)
    M = graph.neighbors.shape[1]
    n = graph.n

    pos = jnp.arange(C, dtype=jnp.int32)[None, :]      # [1, C]
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]     # [B, 1]

    d2_start = batch_dist(queries, start[:, None])[:, 0]
    st = _BeamState(
        cand_ids=jnp.full((B, C), INVALID_ID, jnp.int32).at[:, 0].set(start),
        cand_d2=jnp.full((B, C), jnp.inf, jnp.float32).at[:, 0].set(d2_start),
        cand_vis=jnp.zeros((B, C), jnp.bool_),
        seen=bitset_set(bitset_make(B, n), start[:, None]),
        l=jnp.full((B,), min(max(p.l0, p.k), p.l_max), jnp.int32),
        n_dist=jnp.ones((B,), jnp.int32),
        n_enc=jnp.ones((B,), jnp.int32),
        n_hops=jnp.zeros((B,), jnp.int32),
        done=jnp.zeros((B,), jnp.bool_),
        saturated=jnp.zeros((B,), jnp.bool_),
    )

    def active_mask(s: _BeamState):
        return (~s.done) & (s.n_hops < p.max_hops)

    def cond(s: _BeamState):
        return jnp.any(active_mask(s))

    def body(s: _BeamState) -> _BeamState:
        active = active_mask(s)
        window = (pos < s.l[:, None]) & (s.cand_ids >= 0) & (~s.cand_vis)
        window &= active[:, None]
        has_frontier = jnp.any(window, axis=1)

        # -- frontier selection: W best unvisited in-window per query --------
        sel, selv = select_top_w(s.cand_d2, window, W)
        selv &= (active & has_frontier)[:, None]
        vis_sel = jnp.take_along_axis(s.cand_vis, sel, axis=1) | selv
        cand_vis = s.cand_vis.at[rows, sel].set(vis_sel)
        u_ids = jnp.where(
            selv, jnp.take_along_axis(s.cand_ids, sel, axis=1), INVALID_ID)

        # -- neighbor gather + bitset dedup ---------------------------------
        nbrs = jnp.take(graph.neighbors, jnp.maximum(u_ids, 0), axis=0)
        nbrs = jnp.where(selv[:, :, None], nbrs, INVALID_ID).reshape(B, W * M)
        # encounters: every valid neighbor id this hop produced, pre-dedup —
        # the dedup-independent Exp-5 counter (ROADMAP: the bitset never
        # re-evaluates pruned-then-reencountered nodes, so n_dist undercounts)
        n_enc = s.n_enc + jnp.sum(nbrs >= 0, axis=1).astype(jnp.int32)
        fresh = (nbrs >= 0) & ~bitset_test(s.seen, nbrs)
        new_ids = unique_per_row(nbrs, fresh)                  # [B, W·M]
        seen = bitset_set(s.seen, new_ids)

        # -- the hot path: one fused gather+L2 over the whole batch ----------
        d2_new = batch_dist(queries, new_ids)
        n_evals = jnp.sum(new_ids >= 0, axis=1).astype(jnp.int32)
        n_dist = s.n_dist + n_evals
        n_hops = s.n_hops + jnp.sum(selv, axis=1).astype(jnp.int32)

        if faithful_prune:
            cand_ids, cand_d2, cand_vis, seen = faithful_prune_merge(
                s.cand_ids, s.cand_d2, cand_vis, new_ids, d2_new,
                seen, s.l, C)
        else:
            cand_ids, cand_d2, cand_vis = batch_merge_topc(
                s.cand_ids, s.cand_d2, cand_vis,
                new_ids, d2_new, jnp.zeros_like(fresh), C)

        # -- adaptive transition for window-exhausted queries ----------------
        conv = active & ~has_frontier
        l, done, saturated = adaptive_transition(
            p, cand_d2, s.l, s.done, s.saturated, conv)

        return _BeamState(cand_ids=cand_ids, cand_d2=cand_d2,
                          cand_vis=cand_vis, seen=seen, l=l, n_dist=n_dist,
                          n_enc=n_enc, n_hops=n_hops, done=done,
                          saturated=saturated)

    return jax.lax.while_loop(cond, body, st)


@partial(jax.jit, static_argnames=("params", "faithful_prune",
                                   "with_candidates", "backend"))
def search(
    graph: GraphIndex,
    queries: jax.Array,                 # f32[B, d]
    params: SearchParams,
    start: Optional[jax.Array] = None,  # int32[B] or None → medoid
    faithful_prune: bool = False,
    with_candidates: bool = False,
    backend: str = "auto",
):
    """Batched Alg. 1 / Alg. 3 search on the lock-step beam engine.

    Returns SearchResult (and optionally the final candidate buffers for
    local-optimum analysis).  ``params.beam_width`` sets the per-hop frontier
    width W; W=1 is deterministic greedy best-first (golden-tested for
    run-to-run and cross-backend self-parity).

    ``faithful_prune=True`` runs the literal Alg.-3 top-(l+1) prune on the
    same engine: the candidate buffer is truncated to ``l+1`` every hop and
    pruned-but-never-expanded candidates have their visited bits cleared so
    they can re-enter (and be re-evaluated) when ``l`` grows — see
    ``faithful_prune_merge``.  It composes with any ``beam_width`` and
    ``backend``.
    """
    B = queries.shape[0]
    if start is None:
        start = jnp.broadcast_to(graph.medoid, (B,)).astype(jnp.int32)
    batch_dist = make_batch_dist_fn(graph.vectors, backend)
    st = _beam_search_batch(graph, queries, start, params, batch_dist,
                            faithful_prune=faithful_prune)
    k = params.k
    res = SearchResult(
        ids=st.cand_ids[:, :k],
        dists=jnp.sqrt(jnp.maximum(st.cand_d2[:, :k], 0.0)),
        n_dist_comps=st.n_dist,
        n_approx_comps=jnp.zeros_like(st.n_dist),
        n_hops=st.n_hops,
        final_l=st.l,
        saturated=st.saturated,
        n_encounters=st.n_enc,
    )
    if with_candidates:
        return res, st.cand_ids, jnp.sqrt(jnp.maximum(st.cand_d2, 0.0))
    return res


def greedy_search(graph: GraphIndex, queries: jax.Array, k: int, l: int,
                  start: Optional[jax.Array] = None, max_hops: int = 512,
                  beam_width: int = 1, backend: str = "auto") -> SearchResult:
    """Algorithm 1 with fixed candidate width l (the ablation δ-EMG-GS)."""
    p = SearchParams(k=k, l0=l, l_max=l, adaptive=False, max_hops=max_hops,
                     beam_width=beam_width)
    return search(graph, queries, p, start=start, backend=backend)


def error_bounded_search(graph: GraphIndex, queries: jax.Array, k: int,
                         alpha: float, l_max: int = 256, l_step: int = 1,
                         start: Optional[jax.Array] = None,
                         max_hops: int = 2048, beam_width: int = 1,
                         **kw) -> SearchResult:
    """Algorithm 3: adaptive candidate width with the α stop rule."""
    p = SearchParams(k=k, l0=k, l_max=l_max, l_step=l_step, alpha=alpha,
                     adaptive=True, max_hops=max_hops, beam_width=beam_width)
    return search(graph, queries, p, start=start, **kw)


# ---------------------------------------------------------------------------
# Theorem-4 instrumentation (Exp-6 / Exp-7).
# ---------------------------------------------------------------------------

@jax.jit
def local_optimum_mask(graph: GraphIndex, queries: jax.Array, cand_ids: jax.Array):
    """bool[B, C]: candidate c is a local optimum w.r.t. its query
    (no out-neighbor of c is strictly closer to q than c)."""

    def one(q, ids):
        d2_c = jnp.where(
            ids >= 0,
            jnp.sum((take_rows(graph.vectors, ids) - q[None, :]) ** 2, axis=-1),
            jnp.inf,
        )

        def check(cid, d2c):
            nbrs = jnp.take(graph.neighbors, jnp.maximum(cid, 0), axis=0)
            rows = take_rows(graph.vectors, nbrs)
            d2n = jnp.sum((rows - q[None, :]) ** 2, axis=-1)
            d2n = jnp.where(nbrs >= 0, d2n, jnp.inf)
            return (cid >= 0) & jnp.all(d2n >= d2c)

        return jax.vmap(check)(ids, d2_c)

    return jax.vmap(one)(queries, cand_ids)


def theorem4_delta_prime(graph: GraphIndex, queries: jax.Array, cand_ids: jax.Array,
                         cand_dists: jax.Array, k: int, delta: float):
    """Per-query (found: bool, δ′: f32) per Theorem 4.

    δ′ = δ · d(q, u) / d(q, r_(k)) with u the *farthest* local-optimum node in
    the final candidate set outside the returned top-k (wider search ⇒ larger
    d(q,u) ⇒ tighter bound — Exp-7's observation).
    """
    is_opt = local_optimum_mask(graph, queries, cand_ids)
    pos = jnp.arange(cand_ids.shape[1])[None, :]
    outside = pos >= k
    eligible = is_opt & outside & (cand_ids >= 0) & jnp.isfinite(cand_dists)
    d_u = jnp.max(jnp.where(eligible, cand_dists, -jnp.inf), axis=1)
    found = jnp.any(eligible, axis=1)
    d_rk = cand_dists[:, k - 1]
    delta_prime = jnp.where(found, delta * d_u / jnp.maximum(d_rk, 1e-30), 0.0)
    return found, delta_prime
