"""δ-EMQG assembly (Sec. 6.1): approximate δ-EMG + RaBitQ codes with
degree-aligned neighborhoods.

The paper aligns every out-degree to a multiple of the AVX2 FastScan batch
(32) so no SIMD lanes are wasted.  The TPU analogue: neighbor lists are
padded to exactly ``M`` (we binary-search the adaptive-t rule so real degree
== M where the candidate pool allows), and ``M`` itself should be a multiple
of the 8-row sublane tile so the bitdot/gather kernels run full tiles.
Codes are stored as one global row-major matrix — the CPU version duplicates
codes per-neighborhood for cache locality, which on TPU would multiply HBM
footprint ×M for no DMA benefit (rows are fetched by scalar-prefetch
indexing either way); this deviation is recorded in DESIGN.md §2.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import rabitq
from .build_approx import BuildParams, build_approx
from .types import EMQGIndex, GraphIndex


def build_emqg(vectors, params: Optional[BuildParams] = None,
               key: Optional[jax.Array] = None, verbose: bool = False,
               metrics=None) -> EMQGIndex:
    """Full δ-EMQG build: Algorithm 4 with degree alignment + RaBitQ codes.
    ``metrics``/``verbose`` forward to ``build_approx`` (structured build
    progress events through the obs registry)."""
    if params is None:
        params = BuildParams(align_degree=True)
    elif not params.align_degree:
        params = dataclasses.replace(params, align_degree=True)
    if key is None:
        key = jax.random.PRNGKey(params.seed)
    graph = build_approx(vectors, params, verbose=verbose, metrics=metrics)
    codes = rabitq.fit(graph.vectors, key)
    return EMQGIndex(graph=graph, codes=codes)


def from_graph(graph: GraphIndex, key: Optional[jax.Array] = None) -> EMQGIndex:
    """Attach RaBitQ codes to an existing graph (ablation δ-EMQG-NSG etc.)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    return EMQGIndex(graph=graph, codes=rabitq.fit(graph.vectors, key))


def memory_footprint(index: EMQGIndex) -> dict:
    """Bytes per component — the paper's Fig. 4 'index size' accounting."""
    g, c = index.graph, index.codes
    return {
        "vectors": g.vectors.size * g.vectors.dtype.itemsize,
        "adjacency": g.neighbors.size * 4,
        "codes": c.codes.size * 4,
        "code_scalars": (c.norms.size + c.ip_xo.size) * 4,
        "rotation": c.rotation.size * 4,
    }
