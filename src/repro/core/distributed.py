"""Distributed sharded ANN index — the multi-pod serving path.

Standard scale-out ANN architecture (SPANN/DiskANN-style), expressed in
``shard_map``:

* Dataset rows are partitioned into S shards; each shard holds an
  independent δ-EMG / δ-EMQG over its rows (local id space + global offset).
* A query batch is replicated across the index-sharding axes and sharded
  across the ``pod`` axis (each pod serves its own slice of the request
  stream against a full index replica-set).
* Every device runs the *same* lock-step batched search over its shard, then
  the per-shard top-k are merged exactly:
    - ``merge="all_gather"``: one all-gather of (k ids, k dists) + local
      top-k — one collective, O(S·k·B) bytes per device.
    - ``merge="ring"``: S−1 ``ppermute`` steps each merging two k-lists —
      O((S−1)·k·B) bytes total but pipelined on neighbor links only; this is
      the collective-term optimization evaluated in EXPERIMENTS.md §Perf.

Exactness: top-k over a union of disjoint sets == merge of per-set top-k, so
sharding never loses recall (per-shard search quality is the only
approximation, same as the single-node index).

All index containers are pytrees → ``stack_indices`` builds the [S, ...]
stacked representation with ``tree_map``, and the same code path serves
GraphIndex (Alg. 3) and EMQGIndex (Alg. 5).

Fault tolerance: ``run`` accepts a per-slot validity mask.  A dead slot's
candidates are rewritten to (id=-1, dist=inf) *before* the merge, so both
merge strategies exclude them without a second collective.  The host-side
``ShardHealthRegistry`` tracks per-replica liveness and derives the mask:
with replica groups (``build_replicated``, slot layout ``s·R + r``) exactly
one live replica per logical shard participates — a lost primary fails over
to its replica before coverage degrades at all.  When every replica of a
shard is gone, ``FaultTolerantShardedSearch`` still answers, but each
response carries explicit degradation accounting — ``coverage =
live_shards/S`` and ``max_missed = min(k, Σ_dead min(k, |shard|))``, the
worst case being all of a dead shard's top-k members belonging to the true
global top-k (mirrors the ``1/(δ·α)`` bound reporting in
``serve/resilience.py``).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

if hasattr(jax, "shard_map"):                      # jax ≥ 0.6
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"

from .build_approx import BuildParams, build_approx
from .emqg import build_emqg
from .probing import probing_search
from .search import search
from .types import EMQGIndex, GraphIndex, SearchParams, static_field, _register


@_register
@dataclasses.dataclass(frozen=True)
class ShardedIndex:
    """Stacked per-shard indexes + global id offsets.

    ``index`` leaves have leading dim S.  ``offsets`` is int32[S] — global id
    of local row 0 in each shard.  Shards must be equal-sized (pad the last
    shard by repeating its first row).  ``sizes`` is int32[S] — the number of
    *real* (non-pad) rows in each slot: local ids ``>= sizes[s]`` are pad
    copies of local row 0, and the merge masks them out exactly like
    dead-shard candidates (``id=-1, dist=inf``) — a pad can never leak a
    global id ``>= n_total`` or duplicate its source row's id (the source
    row itself competes in the same local top-k at the same distance).
    ``sizes=None`` (legacy / abstract indexes) treats every row as real.
    """

    index: GraphIndex | EMQGIndex
    offsets: jax.Array
    n_total: int = static_field(default=0)
    sizes: Optional[jax.Array] = None

    @property
    def n_shards(self) -> int:
        return self.offsets.shape[0]

    @property
    def dim(self) -> int:
        g = self.index.graph if isinstance(self.index, EMQGIndex) else self.index
        return int(g.vectors.shape[-1])

    @property
    def delta(self) -> float:
        g = self.index.graph if isinstance(self.index, EMQGIndex) else self.index
        return float(getattr(g, "delta", 0.0))


def stack_indices(indices: Sequence, offsets: Sequence[int], n_total: int,
                  sizes: Optional[Sequence[int]] = None) -> ShardedIndex:
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *indices)
    offsets = jnp.asarray(offsets, jnp.int32)
    if sizes is None:
        # contiguous-partition default: real rows per shard = what remains of
        # n_total past the shard's offset, clipped to the slot capacity
        g = indices[0].graph if isinstance(indices[0], EMQGIndex) else indices[0]
        per = int(g.vectors.shape[0])
        sizes = jnp.clip(n_total - offsets, 0, per)
    return ShardedIndex(index=stacked,
                        offsets=offsets,
                        n_total=n_total,
                        sizes=jnp.asarray(sizes, jnp.int32))


def shard_rows(vectors: np.ndarray, shard: int, per: int) -> tuple[np.ndarray, int]:
    """Rows of contiguous shard ``shard`` (capacity ``per``), padded to
    ``per`` by wrapping the shard's first row (or global row 0 when the shard
    is past the end of the data).  Returns ``(rows, n_real)``.

    This is the canonical shard input: ``build_sharded`` and the repair
    path's from-source rebuild both call it, so a repaired shard is built
    from bit-identical input."""
    vectors = np.asarray(vectors, np.float32)
    rows = vectors[shard * per : (shard + 1) * per]
    n_real = int(rows.shape[0])
    if n_real < per:  # pad by wrapping
        pad = np.tile(rows[:1] if rows.size else vectors[:1],
                      (per - n_real, 1))
        rows = np.concatenate([rows, pad]) if rows.size else pad
    return rows, n_real


def build_shard(rows: np.ndarray, shard: int,
                params: Optional[BuildParams] = None,
                quantized: bool = False, seed: int = 0):
    """Build one shard's index exactly as ``build_sharded`` would (per-shard
    seed derivation ``seed + shard``) — shared with ``core.repair`` so a
    rebuilt shard is bit-identical to the original."""
    p = dataclasses.replace(params or BuildParams(), seed=seed + shard)
    if quantized:
        return build_emqg(rows, p)
    return build_approx(rows, p)


def build_sharded(vectors, n_shards: int, params: Optional[BuildParams] = None,
                  quantized: bool = False, seed: int = 0) -> ShardedIndex:
    """Contiguous row partition; per-shard Algorithm-4 builds (equal-sized,
    last shard padded by wrapping)."""
    vectors = np.asarray(vectors, np.float32)
    n = vectors.shape[0]
    per = int(np.ceil(n / n_shards))
    shards, offsets, sizes = [], [], []
    for s in range(n_shards):
        rows, n_real = shard_rows(vectors, s, per)
        shards.append(build_shard(rows, s, params, quantized, seed))
        offsets.append(s * per)
        sizes.append(n_real)
    return stack_indices(shards, offsets, n, sizes=sizes)


def _local_search(index, queries, params: SearchParams, quantized: bool):
    if quantized:
        return probing_search(index, queries, params)
    return search(index, queries, params)


def _merge_all_gather(ids, dists, k, axis):
    """ids/dists [B, k] per shard → exact global top-k, replicated."""
    all_ids = jax.lax.all_gather(ids, axis, axis=1)      # [B, S, k]
    all_d = jax.lax.all_gather(dists, axis, axis=1)
    B = ids.shape[0]
    flat_i = all_ids.reshape(B, -1)
    flat_d = all_d.reshape(B, -1)
    neg, idx = jax.lax.top_k(-flat_d, k)
    return jnp.take_along_axis(flat_i, idx, axis=1), -neg


def _merge_ring(ids, dists, k, axis, n_shards):
    """(S−1)-step ppermute ring merge; ends replicated (each device has seen
    every shard's list exactly once)."""
    perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]

    def step(carry, _):
        cur_i, cur_d, acc_i, acc_d = carry
        cur_i = jax.lax.ppermute(cur_i, axis, perm)
        cur_d = jax.lax.ppermute(cur_d, axis, perm)
        cat_i = jnp.concatenate([acc_i, cur_i], axis=1)
        cat_d = jnp.concatenate([acc_d, cur_d], axis=1)
        neg, idx = jax.lax.top_k(-cat_d, k)
        return (cur_i, cur_d, jnp.take_along_axis(cat_i, idx, axis=1), -neg), None

    (_, _, acc_i, acc_d), _ = jax.lax.scan(
        step, (ids, dists, ids, dists), None, length=n_shards - 1)
    return acc_i, acc_d


def make_sharded_search(mesh, shard_axes=("data",), query_axis=None,
                        merge: str = "all_gather", quantized: bool = False):
    """Build a jit-able sharded search fn over ``mesh``.

    ``shard_axes``: mesh axes the index shards span (S = their product).
    ``query_axis``: mesh axis (or tuple) the query batch is sharded over
    (None → all queries on every device).  Sharding queries over the axes
    *not* used for index shards turns those axes into throughput parallelism
    — e.g. index over 'data', queries over ('pod','model').
    Returns fn(sharded_index, queries [B, d], params) → (ids, dists) [B, k]
    with outputs replicated over ``shard_axes`` and sharded over
    ``query_axis``.  The ring merge needs a single shard axis (ppermute is
    defined on one mesh axis); multi-axis shards use all_gather.
    """
    axis_name = shard_axes if len(shard_axes) > 1 else shard_axes[0]
    n_shards = int(np.prod([mesh.shape[a] for a in shard_axes]))
    if merge == "ring" and len(shard_axes) > 1:
        raise ValueError("ring merge requires a single shard axis")
    q_spec = P(query_axis) if query_axis else P()

    def body(sidx: ShardedIndex, queries, valid, params: SearchParams):
        local_index = jax.tree.map(lambda x: x[0], sidx.index)
        offset = sidx.offsets[0]
        res = _local_search(local_index, queries, params, quantized)
        # mask dead shards *before* the merge: their candidates become
        # (id=-1, dist=inf) and can never displace a live shard's entry —
        # both merge strategies then exclude them for free
        keep = valid[0] & (res.ids >= 0)
        if sidx.sizes is not None:
            # pad rows (local id >= sizes) are wrapped copies of the shard's
            # first row, whose real copy competes in the same local top-k —
            # mask them like dead-shard entries so no id >= n_total leaks
            # and no id appears twice in the merged top-k
            keep = keep & (res.ids < sidx.sizes[0])
        gids = jnp.where(keep, res.ids + offset, -1)
        d = jnp.where(gids >= 0, res.dists, jnp.inf)
        if merge == "ring":
            mi, md = _merge_ring(gids, d, params.k, axis_name, n_shards)
        else:
            mi, md = _merge_all_gather(gids, d, params.k, axis_name)
        return jnp.where(jnp.isfinite(md), mi, -1), md

    def run(sidx: ShardedIndex, queries, params: SearchParams, valid=None):
        if valid is None:
            valid = jnp.ones((n_shards,), bool)
        index_specs = jax.tree.map(lambda _: P(shard_axes), sidx.index)
        in_specs = (
            ShardedIndex(index=index_specs, offsets=P(shard_axes),
                         n_total=sidx.n_total,
                         sizes=None if sidx.sizes is None else P(shard_axes)),
            q_spec,
            P(shard_axes),
        )
        fn = _shard_map(
            partial(body, params=params),
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(q_spec, q_spec),
            **{_CHECK_KW: False},
        )
        return fn(sidx, queries, jnp.asarray(valid, bool))

    return run


# ---------------------------------------------------------------------------
# Shard health + coverage accounting (module docstring, fault tolerance).
# ---------------------------------------------------------------------------

def build_replicated(vectors, n_shards: int, n_replicas: int = 2,
                     params: Optional[BuildParams] = None,
                     quantized: bool = False, seed: int = 0) -> ShardedIndex:
    """``build_sharded`` with each shard repeated R times — physical slot
    layout ``s·R + r`` (replicas of a shard are adjacent)."""
    base = build_sharded(vectors, n_shards, params, quantized, seed)
    if n_replicas == 1:
        return base
    index = jax.tree.map(lambda x: jnp.repeat(x, n_replicas, axis=0),
                         base.index)
    offsets = jnp.repeat(base.offsets, n_replicas)
    sizes = None if base.sizes is None else jnp.repeat(base.sizes, n_replicas)
    return ShardedIndex(index=index, offsets=offsets, n_total=base.n_total,
                        sizes=sizes)


class ShardHealthRegistry:
    """Host-side liveness over S logical shards × R replicas.

    ``participation()`` is the per-physical-slot mask handed to the sharded
    search: at most ONE live replica per logical shard participates (two
    replicas contributing the same rows would fill the merged top-k with
    duplicate ids).  A logical shard is covered iff any replica is live.

    Liveness can be driven two ways: explicitly (``mark_dead`` /
    ``mark_live`` — the operator surface, and what the fault harness's
    ``ShardDeathPlan`` calls) or implicitly via **heartbeats** — every
    replica records ``heartbeat()`` timestamps on the injectable monotonic
    ``clock``, and a :class:`DeadlineHealthChecker` auto-``mark_dead``s any
    live replica whose heartbeat age exceeds its deadline.  ``publish``
    mirrors the state into an ``obs`` registry (``shard_live{shard}``,
    ``shard_coverage``, ``shard_failover`` gauges).
    """

    def __init__(self, n_shards: int, n_replicas: int = 1,
                 clock=None):
        import time as _time
        self.n_shards = n_shards
        self.n_replicas = n_replicas
        self.clock = clock if clock is not None else _time.perf_counter
        self._live = np.ones((n_shards, n_replicas), bool)
        now = self.clock()
        self._last_beat = np.full((n_shards, n_replicas), now, float)

    def mark_dead(self, shard: int, replica: int = 0) -> None:
        self._live[shard, replica] = False

    def mark_live(self, shard: int, replica: int = 0) -> None:
        self._live[shard, replica] = True
        self._last_beat[shard, replica] = self.clock()

    def heartbeat(self, shard: int, replica: int = 0,
                  now: Optional[float] = None) -> None:
        """Record a liveness heartbeat for one replica (does NOT revive a
        slot already marked dead — a zombie's late beat must not undo an
        operator/checker kill; use ``mark_live`` for explicit revival)."""
        self._last_beat[shard, replica] = \
            now if now is not None else self.clock()

    def heartbeat_age(self, shard: int, replica: int = 0,
                      now: Optional[float] = None) -> float:
        now = now if now is not None else self.clock()
        return float(now - self._last_beat[shard, replica])

    def publish(self, metrics) -> None:
        """Mirror liveness into an ``obs.MetricsRegistry`` as gauges."""
        for s in range(self.n_shards):
            metrics.gauge("shard_live", {"shard": s}).set(
                float(self._live[s].any()))
        metrics.gauge("shard_coverage").set(self.coverage())
        metrics.gauge("shard_failover").set(self.n_failover)

    def live_shards(self) -> list[int]:
        return [s for s in range(self.n_shards) if self._live[s].any()]

    def dead_shards(self) -> list[int]:
        return [s for s in range(self.n_shards) if not self._live[s].any()]

    def coverage(self) -> float:
        return len(self.live_shards()) / self.n_shards

    @property
    def n_failover(self) -> int:
        """Logical shards currently served by a non-primary replica."""
        return int(sum(1 for s in range(self.n_shards)
                       if not self._live[s, 0] and self._live[s].any()))

    def participation(self) -> np.ndarray:
        """bool[S·R] — first live replica of each logical shard."""
        mask = np.zeros((self.n_shards, self.n_replicas), bool)
        for s in range(self.n_shards):
            alive = np.where(self._live[s])[0]
            if alive.size:
                mask[s, alive[0]] = True
        return mask.ravel()


class DeadlineHealthChecker:
    """Deadline-based shard health: a live replica whose last heartbeat is
    older than ``deadline_s`` is automatically ``mark_dead``-ed.

    This closes the loop the operator surface left open — ``kill_shard``
    required someone to *notice* the failure; the checker notices.  Call
    :meth:`check` from the serve loop (it is O(S·R) numpy reads — cheap per
    batch) or a timer.  Deterministically testable: both the registry clock
    and ``check(now=...)`` are injectable, so a fault schedule can age
    heartbeats without sleeping.

    With ``metrics``, every check refreshes two gauge families:
    ``shard_replica_heartbeat_age_seconds{shard,replica}`` — the raw
    heartbeat age of every slot, live or dead (what the deadline is compared
    against, per replica) — and the per-shard rollup
    ``shard_heartbeat_age_seconds{shard}``, which is the **min** age over the
    shard's *live* replicas (the freshest live replica; ``inf`` when every
    replica is dead — the shard-level "how stale is the healthiest copy"
    signal).  It also bumps ``shard_marked_dead_total`` per kill, emits a
    ``shard_deadline_expired`` structured event, and republishes the
    liveness gauges.
    """

    def __init__(self, registry: ShardHealthRegistry, deadline_s: float,
                 metrics=None):
        if deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        self.registry = registry
        self.deadline_s = float(deadline_s)
        self.metrics = metrics
        self.n_checks = 0
        self.n_killed = 0

    def check(self, now: Optional[float] = None) -> list[tuple[int, int]]:
        """One sweep; returns the (shard, replica) slots killed this call."""
        reg = self.registry
        now = now if now is not None else reg.clock()
        self.n_checks += 1
        killed: list[tuple[int, int]] = []
        for s in range(reg.n_shards):
            for r in range(reg.n_replicas):
                age = reg.heartbeat_age(s, r, now=now)
                if self.metrics is not None:
                    self.metrics.gauge(
                        "shard_replica_heartbeat_age_seconds",
                        {"shard": s, "replica": r}).set(age)
                if not reg._live[s, r]:
                    continue
                if age > self.deadline_s:
                    reg.mark_dead(s, r)
                    killed.append((s, r))
                    self.n_killed += 1
                    if self.metrics is not None:
                        self.metrics.counter("shard_marked_dead_total").inc()
                        self.metrics.event(
                            "shard_deadline_expired", shard=s, replica=r,
                            age_s=age, deadline_s=self.deadline_s)
            if self.metrics is not None:
                live = np.where(reg._live[s])[0]
                age_s = min((reg.heartbeat_age(s, r, now=now) for r in live),
                            default=math.inf)
                self.metrics.gauge("shard_heartbeat_age_seconds",
                                   {"shard": s}).set(age_s)
        if self.metrics is not None:
            reg.publish(self.metrics)
        return killed


@dataclasses.dataclass(frozen=True)
class ShardedSearchResult:
    """Merged top-k plus explicit per-response degradation accounting."""

    ids: jax.Array                 # [B, k] global ids (-1 where unfilled)
    dists: jax.Array               # [B, k]
    coverage: float                # live logical shards / S
    live_shards: int
    n_shards: int
    max_missed: int                # worst-case true neighbors lost to dead shards
    failover: int                  # shards answered by a non-primary replica


class FaultTolerantShardedSearch:
    """Host wrapper: registry-masked sharded search with coverage accounting.

    The mask is recomputed from the registry on every call, so marking a
    shard dead (or a replica live again) takes effect on the next query
    batch without re-tracing — ``valid`` is a runtime array input.
    """

    def __init__(self, sidx: ShardedIndex, mesh, shard_axes=("data",),
                 query_axis=None, merge: str = "all_gather",
                 quantized: bool = False, n_replicas: int = 1,
                 registry: Optional[ShardHealthRegistry] = None):
        n_slots = sidx.n_shards
        if n_slots % n_replicas:
            raise ValueError(f"{n_slots} slots not divisible by "
                             f"{n_replicas} replicas")
        self.sidx = sidx
        self.quantized = quantized
        # a shared registry lets several searchers (e.g. the two merge
        # strategies of a resilient server) see one liveness truth
        self.registry = registry if registry is not None else \
            ShardHealthRegistry(n_slots // n_replicas, n_replicas)
        if self.registry.n_shards * self.registry.n_replicas != n_slots:
            raise ValueError("registry shape does not match index slots")
        self._run = make_sharded_search(mesh, shard_axes=shard_axes,
                                        query_axis=query_axis, merge=merge,
                                        quantized=quantized)
        if sidx.sizes is not None:
            self.shard_sizes = np.asarray(sidx.sizes)[::n_replicas].astype(int)
        else:
            offs = np.asarray(sidx.offsets)[::n_replicas]
            self.shard_sizes = np.diff(
                np.append(offs, sidx.n_total)).astype(int)

    def __call__(self, queries, params: SearchParams) -> ShardedSearchResult:
        mask = self.registry.participation()
        if not mask.any():
            raise RuntimeError("no live shard replicas")
        ids, dists = self._run(self.sidx, queries, params, valid=mask)
        dead = self.registry.dead_shards()
        max_missed = int(min(params.k,
                             sum(min(params.k, self.shard_sizes[s])
                                 for s in dead)))
        return ShardedSearchResult(
            ids=ids, dists=dists,
            coverage=self.registry.coverage(),
            live_shards=len(self.registry.live_shards()),
            n_shards=self.registry.n_shards,
            max_missed=max_missed,
            failover=self.registry.n_failover)


def host_reference_merge(sidx: ShardedIndex, registry: ShardHealthRegistry,
                         queries, params: SearchParams,
                         quantized: bool = False):
    """Oracle for the masked merge: per-slot searches on the host, merged
    over exactly the participating slots.  O(S) sequential searches — test
    and audit use only."""
    mask = registry.participation()
    all_i, all_d = [], []
    for slot in np.where(mask)[0]:
        local = jax.tree.map(lambda x, s=slot: x[s], sidx.index)
        res = _local_search(local, queries, params, quantized)
        ids = np.asarray(res.ids)
        offs = int(np.asarray(sidx.offsets)[slot])
        keep = ids >= 0
        if sidx.sizes is not None:
            keep &= ids < int(np.asarray(sidx.sizes)[slot])
        all_i.append(np.where(keep, ids + offs, -1))
        all_d.append(np.where(keep, np.asarray(res.dists), np.inf))
    cat_i = np.concatenate(all_i, axis=1)
    cat_d = np.concatenate(all_d, axis=1)
    order = np.argsort(cat_d, axis=1, kind="stable")[:, : params.k]
    mi = np.take_along_axis(cat_i, order, axis=1)
    md = np.take_along_axis(cat_d, order, axis=1)
    return np.where(np.isfinite(md), mi, -1), md
