"""Distributed sharded ANN index — the multi-pod serving path.

Standard scale-out ANN architecture (SPANN/DiskANN-style), expressed in
``shard_map``:

* Dataset rows are partitioned into S shards; each shard holds an
  independent δ-EMG / δ-EMQG over its rows (local id space + global offset).
* A query batch is replicated across the index-sharding axes and sharded
  across the ``pod`` axis (each pod serves its own slice of the request
  stream against a full index replica-set).
* Every device runs the *same* lock-step batched search over its shard, then
  the per-shard top-k are merged exactly:
    - ``merge="all_gather"``: one all-gather of (k ids, k dists) + local
      top-k — one collective, O(S·k·B) bytes per device.
    - ``merge="ring"``: S−1 ``ppermute`` steps each merging two k-lists —
      O((S−1)·k·B) bytes total but pipelined on neighbor links only; this is
      the collective-term optimization evaluated in EXPERIMENTS.md §Perf.

Exactness: top-k over a union of disjoint sets == merge of per-set top-k, so
sharding never loses recall (per-shard search quality is the only
approximation, same as the single-node index).

All index containers are pytrees → ``stack_indices`` builds the [S, ...]
stacked representation with ``tree_map``, and the same code path serves
GraphIndex (Alg. 3) and EMQGIndex (Alg. 5).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

if hasattr(jax, "shard_map"):                      # jax ≥ 0.6
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"

from .build_approx import BuildParams, build_approx
from .emqg import build_emqg
from .probing import probing_search
from .search import search
from .types import EMQGIndex, GraphIndex, SearchParams, static_field, _register


@_register
@dataclasses.dataclass(frozen=True)
class ShardedIndex:
    """Stacked per-shard indexes + global id offsets.

    ``index`` leaves have leading dim S.  ``offsets`` is int32[S] — global id
    of local row 0 in each shard.  Shards must be equal-sized (pad the last
    shard by repeating its first row; duplicate results are dedup-safe
    because merge keeps the closer copy and ids are identical).
    """

    index: GraphIndex | EMQGIndex
    offsets: jax.Array
    n_total: int = static_field(default=0)

    @property
    def n_shards(self) -> int:
        return self.offsets.shape[0]


def stack_indices(indices: Sequence, offsets: Sequence[int], n_total: int) -> ShardedIndex:
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *indices)
    return ShardedIndex(index=stacked,
                        offsets=jnp.asarray(offsets, jnp.int32),
                        n_total=n_total)


def build_sharded(vectors, n_shards: int, params: Optional[BuildParams] = None,
                  quantized: bool = False, seed: int = 0) -> ShardedIndex:
    """Contiguous row partition; per-shard Algorithm-4 builds (equal-sized,
    last shard padded by wrapping)."""
    vectors = np.asarray(vectors, np.float32)
    n = vectors.shape[0]
    per = int(np.ceil(n / n_shards))
    shards, offsets = [], []
    for s in range(n_shards):
        lo = s * per
        rows = vectors[lo : lo + per]
        if rows.shape[0] < per:  # pad by wrapping
            pad = np.tile(rows[:1] if rows.size else vectors[:1],
                          (per - rows.shape[0], 1))
            rows = np.concatenate([rows, pad]) if rows.size else pad
        p = params or BuildParams()
        p = dataclasses.replace(p, seed=seed + s)
        if quantized:
            shards.append(build_emqg(rows, p))
        else:
            shards.append(build_approx(rows, p))
        offsets.append(lo)
    return stack_indices(shards, offsets, n)


def _local_search(index, queries, params: SearchParams, quantized: bool):
    if quantized:
        return probing_search(index, queries, params)
    return search(index, queries, params)


def _merge_all_gather(ids, dists, k, axis):
    """ids/dists [B, k] per shard → exact global top-k, replicated."""
    all_ids = jax.lax.all_gather(ids, axis, axis=1)      # [B, S, k]
    all_d = jax.lax.all_gather(dists, axis, axis=1)
    B = ids.shape[0]
    flat_i = all_ids.reshape(B, -1)
    flat_d = all_d.reshape(B, -1)
    neg, idx = jax.lax.top_k(-flat_d, k)
    return jnp.take_along_axis(flat_i, idx, axis=1), -neg


def _merge_ring(ids, dists, k, axis, n_shards):
    """(S−1)-step ppermute ring merge; ends replicated (each device has seen
    every shard's list exactly once)."""
    perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]

    def step(carry, _):
        cur_i, cur_d, acc_i, acc_d = carry
        cur_i = jax.lax.ppermute(cur_i, axis, perm)
        cur_d = jax.lax.ppermute(cur_d, axis, perm)
        cat_i = jnp.concatenate([acc_i, cur_i], axis=1)
        cat_d = jnp.concatenate([acc_d, cur_d], axis=1)
        neg, idx = jax.lax.top_k(-cat_d, k)
        return (cur_i, cur_d, jnp.take_along_axis(cat_i, idx, axis=1), -neg), None

    (_, _, acc_i, acc_d), _ = jax.lax.scan(
        step, (ids, dists, ids, dists), None, length=n_shards - 1)
    return acc_i, acc_d


def make_sharded_search(mesh, shard_axes=("data",), query_axis=None,
                        merge: str = "all_gather", quantized: bool = False):
    """Build a jit-able sharded search fn over ``mesh``.

    ``shard_axes``: mesh axes the index shards span (S = their product).
    ``query_axis``: mesh axis (or tuple) the query batch is sharded over
    (None → all queries on every device).  Sharding queries over the axes
    *not* used for index shards turns those axes into throughput parallelism
    — e.g. index over 'data', queries over ('pod','model').
    Returns fn(sharded_index, queries [B, d], params) → (ids, dists) [B, k]
    with outputs replicated over ``shard_axes`` and sharded over
    ``query_axis``.  The ring merge needs a single shard axis (ppermute is
    defined on one mesh axis); multi-axis shards use all_gather.
    """
    axis_name = shard_axes if len(shard_axes) > 1 else shard_axes[0]
    n_shards = int(np.prod([mesh.shape[a] for a in shard_axes]))
    if merge == "ring" and len(shard_axes) > 1:
        raise ValueError("ring merge requires a single shard axis")
    q_spec = P(query_axis) if query_axis else P()

    def body(sidx: ShardedIndex, queries, params: SearchParams):
        local_index = jax.tree.map(lambda x: x[0], sidx.index)
        offset = sidx.offsets[0]
        res = _local_search(local_index, queries, params, quantized)
        gids = jnp.where(res.ids >= 0, res.ids + offset, res.ids)
        if merge == "ring":
            return _merge_ring(gids, res.dists, params.k, axis_name, n_shards)
        return _merge_all_gather(gids, res.dists, params.k, axis_name)

    def run(sidx: ShardedIndex, queries, params: SearchParams):
        index_specs = jax.tree.map(lambda _: P(shard_axes), sidx.index)
        in_specs = (
            ShardedIndex(index=index_specs, offsets=P(shard_axes), n_total=sidx.n_total),
            q_spec,
        )
        fn = _shard_map(
            partial(body, params=params),
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(q_spec, q_spec),
            **{_CHECK_KW: False},
        )
        return fn(sidx, queries)

    return run
