"""Algorithm 5 — Probing top-k ANN search on δ-EMQG.

Two-tier traversal: *expansion* walks the graph using RaBitQ approximate
distances (cheap, batched over a node's whole neighbor list); *probing*
promotes the best approximate candidate to the exact tier only when the
exact frontier has stopped improving.  The adaptive outer-``l`` loop and the
α stop rule are inherited from Algorithm 3 and apply to the exact tier.

Like ``search.py``, two engines:

``probing_search``        — the batch-level beam engine.  One ``while_loop``
                            drives the whole batch; per iteration each query
                            either *probes* its ``beam_width`` best unprobed
                            approximate candidates (their exact distances are
                            evaluated in one fused gather+L2 call over
                            ``[B, W]`` ids) or *expands* its W best unvisited
                            exact candidates (``B×W×M`` neighbor ids deduped
                            against a packed visited bitset, approximate
                            distances in one batched RaBitQ estimate).  The
                            NeedProbing rule (lines 22-28) decides per query;
                            finished queries are masked no-ops.

``legacy_probing_search`` — the seed per-query engine (``vmap`` over a
                            per-query ``while_loop``, one op per hop,
                            ring-buffer dedup).  Parity oracle.

Fixed-shape state (either engine):

  C_e — exact candidates  (ids, exact d², visited flags)   cap l_max+1
  C_a — approx candidates (ids, approx d², probed flags)   cap l_max+1

Also provides AGS (approximate greedy search + exact rerank — SymphonyQG's
search, the paper's δ-EMQG-AGS ablation).
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import rabitq
from .bitset import bitset_make, bitset_set, bitset_test, unique_per_row
from .search import (
    _merge_topc,
    _search_one,
    adaptive_transition,
    batch_merge_topc,
    make_batch_dist_fn,
    make_exact_dist_fn,
    resolve_beam_width,
    select_top_w,
)
from .types import INVALID_ID, EMQGIndex, SearchParams, SearchResult


# ---------------------------------------------------------------------------
# Batch-level beam engine.
# ---------------------------------------------------------------------------


class _BeamPState(NamedTuple):
    ce_ids: jax.Array      # int32[B, C]  exact tier
    ce_d2: jax.Array       # f32[B, C]
    ce_vis: jax.Array      # bool[B, C]
    ca_ids: jax.Array      # int32[B, C]  approx tier
    ca_d2: jax.Array       # f32[B, C]
    ca_prb: jax.Array      # bool[B, C]
    seen: jax.Array        # uint32[B, nw] every id that entered either tier
    d2_last: jax.Array     # f32[B]  exact d² of the last expanded node
    l: jax.Array           # int32[B]
    n_dist: jax.Array      # int32[B]
    n_approx: jax.Array    # int32[B]
    n_enc: jax.Array       # int32[B]  candidate encounters (pre-dedup)
    n_hops: jax.Array      # int32[B]
    done: jax.Array        # bool[B]
    saturated: jax.Array   # bool[B]


def _beam_probing_batch(
    neighbors: jax.Array,      # int32[n, M]
    n_nodes: int,
    batch_exact: Callable,     # (queries [B,d], ids [B,K]) → d2 [B,K]
    batch_approx: Callable,    # (ids [B,K]) → d2 [B,K]
    queries: jax.Array,
    start: jax.Array,
    p: SearchParams,
) -> _BeamPState:
    B = queries.shape[0]
    C = p.l_max + 1
    W = resolve_beam_width(p, C)
    M = neighbors.shape[1]

    pos = jnp.arange(C, dtype=jnp.int32)[None, :]
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]

    d2_s = batch_exact(queries, start[:, None])[:, 0]
    st = _BeamPState(
        ce_ids=jnp.full((B, C), INVALID_ID, jnp.int32).at[:, 0].set(start),
        ce_d2=jnp.full((B, C), jnp.inf, jnp.float32).at[:, 0].set(d2_s),
        ce_vis=jnp.zeros((B, C), jnp.bool_),
        ca_ids=jnp.full((B, C), INVALID_ID, jnp.int32),
        ca_d2=jnp.full((B, C), jnp.inf, jnp.float32),
        ca_prb=jnp.zeros((B, C), jnp.bool_),
        seen=bitset_set(bitset_make(B, n_nodes), start[:, None]),
        d2_last=d2_s,
        l=jnp.full((B,), min(max(p.l0, p.k), p.l_max), jnp.int32),
        n_dist=jnp.ones((B,), jnp.int32),
        n_approx=jnp.zeros((B,), jnp.int32),
        n_enc=jnp.ones((B,), jnp.int32),
        n_hops=jnp.zeros((B,), jnp.int32),
        done=jnp.zeros((B,), jnp.bool_),
        saturated=jnp.zeros((B,), jnp.bool_),
    )

    def active_mask(s: _BeamPState):
        return (~s.done) & (s.n_hops < p.max_hops)

    def cond(s: _BeamPState):
        return jnp.any(active_mask(s))

    def body(s: _BeamPState) -> _BeamPState:
        active = active_mask(s)
        win_e = (pos < s.l[:, None]) & (s.ce_ids >= 0) & (~s.ce_vis)
        win_e &= active[:, None]
        win_a = (pos < s.l[:, None]) & (s.ca_ids >= 0) & (~s.ca_prb)
        win_a &= active[:, None]
        has_u = jnp.any(win_e, axis=1)
        has_w = jnp.any(win_a, axis=1)
        d2_u = jnp.min(jnp.where(win_e, s.ce_d2, jnp.inf), axis=1)
        d2_w = jnp.min(jnp.where(win_a, s.ca_d2, jnp.inf), axis=1)

        # NeedProbing (lines 22-28): probe when the exact frontier stopped
        # improving and the approx tier has something closer.
        need_probe = jnp.where(
            ~has_u,
            has_w,
            (d2_u > s.d2_last) & has_w & (d2_w < d2_u),
        )
        probing = active & need_probe
        expanding = active & ~need_probe & has_u
        conv = active & ~has_u & ~has_w

        # -- probe branch: exact distances for W best unprobed approx --------
        sel_w, selv_w = select_top_w(s.ca_d2, win_a, W)
        selv_w &= probing[:, None]
        prb_sel = jnp.take_along_axis(s.ca_prb, sel_w, axis=1) | selv_w
        ca_prb = s.ca_prb.at[rows, sel_w].set(prb_sel)
        w_ids = jnp.where(
            selv_w, jnp.take_along_axis(s.ca_ids, sel_w, axis=1), INVALID_ID)
        d2_probe = batch_exact(queries, w_ids)                 # [B, W] fused
        n_dist = s.n_dist + jnp.sum(w_ids >= 0, axis=1).astype(jnp.int32)

        # -- expand branch: approx distances for W·M neighbor ids ------------
        sel_u, selv_u = select_top_w(s.ce_d2, win_e, W)
        selv_u &= expanding[:, None]
        vis_sel = jnp.take_along_axis(s.ce_vis, sel_u, axis=1) | selv_u
        ce_vis = s.ce_vis.at[rows, sel_u].set(vis_sel)
        u_ids = jnp.where(
            selv_u, jnp.take_along_axis(s.ce_ids, sel_u, axis=1), INVALID_ID)
        d2_u_sel = jnp.where(
            selv_u, jnp.take_along_axis(s.ce_d2, sel_u, axis=1), -jnp.inf)
        # "last expanded" = the worst of this hop's frontier (W=1: exactly u).
        d2_last = jnp.where(expanding, jnp.max(d2_u_sel, axis=1), s.d2_last)

        nbrs = jnp.take(neighbors, jnp.maximum(u_ids, 0), axis=0)
        nbrs = jnp.where(selv_u[:, :, None], nbrs, INVALID_ID).reshape(B, W * M)
        fresh = (nbrs >= 0) & ~bitset_test(s.seen, nbrs)
        new_ids = unique_per_row(nbrs, fresh)
        seen = bitset_set(s.seen, new_ids)
        d2a = batch_approx(new_ids)                            # [B, W·M]
        n_approx = s.n_approx + jnp.sum(new_ids >= 0, axis=1).astype(jnp.int32)
        # encounters: valid neighbor ids pre-dedup, plus probed candidates
        n_enc = s.n_enc + jnp.sum(nbrs >= 0, axis=1).astype(jnp.int32) \
            + jnp.sum(w_ids >= 0, axis=1).astype(jnp.int32)

        n_hops = s.n_hops + jnp.sum(selv_w, axis=1).astype(jnp.int32) \
            + jnp.sum(selv_u, axis=1).astype(jnp.int32)

        # -- merges (per query only one branch contributes real entries) -----
        ce_ids, ce_d2, ce_vis = batch_merge_topc(
            s.ce_ids, s.ce_d2, ce_vis,
            w_ids, d2_probe, jnp.zeros_like(w_ids, jnp.bool_), C)
        ca_ids, ca_d2, ca_prb = batch_merge_topc(
            s.ca_ids, s.ca_d2, ca_prb,
            new_ids, d2a, jnp.zeros_like(fresh), C)

        # -- adaptive transition for exhausted queries -----------------------
        l, done, saturated = adaptive_transition(
            p, ce_d2, s.l, s.done, s.saturated, conv)

        return _BeamPState(
            ce_ids=ce_ids, ce_d2=ce_d2, ce_vis=ce_vis,
            ca_ids=ca_ids, ca_d2=ca_d2, ca_prb=ca_prb,
            seen=seen, d2_last=d2_last, l=l, n_dist=n_dist,
            n_approx=n_approx, n_enc=n_enc, n_hops=n_hops, done=done,
            saturated=saturated)

    return jax.lax.while_loop(cond, body, st)


@partial(jax.jit, static_argnames=("params", "use_kernel", "with_candidates",
                                   "backend"))
def probing_search(
    index: EMQGIndex,
    queries: jax.Array,
    params: SearchParams,
    start: Optional[jax.Array] = None,
    use_kernel: bool = False,
    with_candidates: bool = False,
    backend: str = "auto",
):
    """Batched Algorithm 5 on the lock-step beam engine.  ``use_kernel``
    routes the S₊ contraction through the Pallas bitdot kernel
    (interpret-mode on CPU); ``backend`` selects the exact-tier gather+L2
    implementation (see ``make_batch_dist_fn``)."""
    B = queries.shape[0]
    g, codes = index.graph, index.codes
    if start is None:
        start = jnp.broadcast_to(g.medoid, (B,)).astype(jnp.int32)
    batch_exact = make_batch_dist_fn(g.vectors, backend)
    bitdot_fn = None
    if use_kernel:
        from repro.kernels.bitdot.ops import bitdot as bitdot_fn  # lazy: optional dep

    ctx = jax.vmap(lambda q: rabitq.prepare_query(codes, q))(queries)

    def batch_approx(ids):
        return jax.vmap(
            lambda c, i: rabitq.estimate_sqdist(codes, c, i, bitdot_fn=bitdot_fn)
        )(ctx, ids)

    st = _beam_probing_batch(g.neighbors, g.n, batch_exact, batch_approx,
                             queries, start, params)
    k = params.k
    res = SearchResult(
        ids=st.ce_ids[:, :k],
        dists=jnp.sqrt(jnp.maximum(st.ce_d2[:, :k], 0.0)),
        n_dist_comps=st.n_dist,
        n_approx_comps=st.n_approx,
        n_hops=st.n_hops,
        final_l=st.l,
        saturated=st.saturated,
        n_encounters=st.n_enc,
    )
    if with_candidates:
        return res, st.ce_ids, jnp.sqrt(jnp.maximum(st.ce_d2, 0.0))
    return res


# ---------------------------------------------------------------------------
# Legacy per-query engine (parity oracle — see module docstring).
# ---------------------------------------------------------------------------


class _PState(NamedTuple):
    ce_ids: jax.Array
    ce_d2: jax.Array
    ce_vis: jax.Array
    ca_ids: jax.Array
    ca_d2: jax.Array
    ca_prb: jax.Array
    t_ids: jax.Array
    t_cnt: jax.Array
    d2_last: jax.Array
    l: jax.Array
    n_dist: jax.Array
    n_approx: jax.Array
    n_enc: jax.Array
    n_hops: jax.Array
    done: jax.Array
    saturated: jax.Array


def _probing_one(neighbors, exact_fn, approx_fn, q, ctx, start, p: SearchParams):
    C = p.l_max + 1
    T = 2 * p.max_hops  # both tiers feed the ring

    d2_s = exact_fn(q, start[None])[0]
    st = _PState(
        ce_ids=jnp.full((C,), INVALID_ID, jnp.int32).at[0].set(start),
        ce_d2=jnp.full((C,), jnp.inf, jnp.float32).at[0].set(d2_s),
        ce_vis=jnp.zeros((C,), jnp.bool_),
        ca_ids=jnp.full((C,), INVALID_ID, jnp.int32),
        ca_d2=jnp.full((C,), jnp.inf, jnp.float32),
        ca_prb=jnp.zeros((C,), jnp.bool_),
        t_ids=jnp.full((T,), INVALID_ID, jnp.int32).at[0].set(start),
        t_cnt=jnp.int32(1),
        d2_last=d2_s,
        l=jnp.int32(min(max(p.l0, p.k), p.l_max)),
        n_dist=jnp.int32(1),
        n_approx=jnp.int32(0),
        n_enc=jnp.int32(1),
        n_hops=jnp.int32(0),
        done=jnp.bool_(False),
        saturated=jnp.bool_(False),
    )
    pos = jnp.arange(C, dtype=jnp.int32)
    alpha2 = jnp.float32(p.alpha * p.alpha)

    def best_unvisited(ids, d2, vis, l):
        mask = (pos < l) & (ids >= 0) & (~vis)
        sel = jnp.argmin(jnp.where(mask, d2, jnp.inf))
        has = jnp.any(mask)
        return has, sel

    def cond(s: _PState):
        return (~s.done) & (s.n_hops < p.max_hops)

    def expand(s: _PState, sel_u) -> _PState:
        """Line 13-16: expand u with approximate distances into C_a."""
        u_id = s.ce_ids[sel_u]
        d2_u = s.ce_d2[sel_u]
        ce_vis = s.ce_vis.at[sel_u].set(True)
        nbrs = jnp.take(neighbors, jnp.maximum(u_id, 0), axis=0)
        valid = nbrs >= 0
        in_t = jnp.any(nbrs[:, None] == s.t_ids[None, :], axis=1)
        in_ca = jnp.any(nbrs[:, None] == s.ca_ids[None, :], axis=1)
        fresh = valid & ~in_t & ~in_ca
        d2a = approx_fn(ctx, jnp.where(fresh, nbrs, INVALID_ID))
        n_approx = s.n_approx + jnp.sum(fresh).astype(jnp.int32)
        ca_ids, ca_d2, ca_prb = _merge_topc(
            s.ca_ids, s.ca_d2, s.ca_prb,
            jnp.where(fresh, nbrs, INVALID_ID),
            jnp.where(fresh, d2a, jnp.inf),
            jnp.zeros_like(fresh), C,
        )
        return s._replace(ce_vis=ce_vis, ca_ids=ca_ids, ca_d2=ca_d2,
                          ca_prb=ca_prb, d2_last=d2_u, n_approx=n_approx,
                          n_enc=s.n_enc + jnp.sum(valid).astype(jnp.int32),
                          n_hops=s.n_hops + 1)

    def probe(s: _PState, sel_w) -> _PState:
        """Line 9-11: compute the exact distance of w, promote to C_e."""
        w_id = s.ca_ids[sel_w]
        ca_prb = s.ca_prb.at[sel_w].set(True)
        t_ids = s.t_ids.at[s.t_cnt % T].set(w_id)
        t_cnt = s.t_cnt + 1
        d2_w = exact_fn(q, w_id[None])[0]
        one_id = jnp.full((1,), 0, jnp.int32).at[0].set(w_id)
        ce_ids, ce_d2, ce_vis = _merge_topc(
            s.ce_ids, s.ce_d2, s.ce_vis,
            one_id, d2_w[None], jnp.zeros((1,), jnp.bool_), C,
        )
        return s._replace(ce_ids=ce_ids, ce_d2=ce_d2, ce_vis=ce_vis,
                          ca_prb=ca_prb, t_ids=t_ids, t_cnt=t_cnt,
                          n_dist=s.n_dist + 1, n_enc=s.n_enc + 1,
                          n_hops=s.n_hops + 1)

    def converged(s: _PState) -> _PState:
        if not p.adaptive:
            return s._replace(done=jnp.bool_(True))
        d2_l = s.ce_d2[jnp.minimum(s.l - 1, C - 1)]
        d2_k = s.ce_d2[p.k - 1]
        stop = d2_l >= alpha2 * d2_k
        at_cap = s.l >= p.l_max
        return s._replace(
            l=jnp.where(stop, s.l, jnp.minimum(s.l + p.l_step, p.l_max)),
            done=stop | at_cap,
            saturated=s.saturated | (at_cap & ~stop),
        )

    def body(s: _PState) -> _PState:
        has_u, sel_u = best_unvisited(s.ce_ids, s.ce_d2, s.ce_vis, s.l)
        has_w, sel_w = best_unvisited(s.ca_ids, s.ca_d2, s.ca_prb, s.l)
        d2_u = jnp.where(has_u, s.ce_d2[sel_u], jnp.inf)
        d2_w = jnp.where(has_w, s.ca_d2[sel_w], jnp.inf)
        # NeedProbing (lines 22-28)
        need_probe = jnp.where(
            ~has_u,
            has_w,
            (d2_u > s.d2_last) & has_w & (d2_w < d2_u),
        )
        exhausted = ~has_u & ~has_w

        def do_converged(s):
            return converged(s)

        def do_step(s):
            return jax.lax.cond(
                need_probe, lambda s_: probe(s_, sel_w), lambda s_: expand(s_, sel_u), s
            )

        return jax.lax.cond(exhausted, do_converged, do_step, s)

    return jax.lax.while_loop(cond, body, st)


@partial(jax.jit, static_argnames=("params", "use_kernel", "with_candidates"))
def legacy_probing_search(
    index: EMQGIndex,
    queries: jax.Array,
    params: SearchParams,
    start: Optional[jax.Array] = None,
    use_kernel: bool = False,
    with_candidates: bool = False,
):
    """Seed per-query Algorithm 5 engine.  Parity oracle for
    ``probing_search``; not on any hot path."""
    B = queries.shape[0]
    g, codes = index.graph, index.codes
    if start is None:
        start = jnp.broadcast_to(g.medoid, (B,)).astype(jnp.int32)
    exact_fn = make_exact_dist_fn(g.vectors)
    bitdot_fn = None
    if use_kernel:
        from repro.kernels.bitdot.ops import bitdot as bitdot_fn  # lazy: optional dep

    def approx_fn(ctx, ids):
        return rabitq.estimate_sqdist(codes, ctx, ids, bitdot_fn=bitdot_fn)

    def one(q, s0):
        ctx = rabitq.prepare_query(codes, q)
        return _probing_one(g.neighbors, exact_fn, approx_fn, q, ctx, s0, params)

    st = jax.vmap(one)(queries, start)
    k = params.k
    res = SearchResult(
        ids=st.ce_ids[:, :k],
        dists=jnp.sqrt(jnp.maximum(st.ce_d2[:, :k], 0.0)),
        n_dist_comps=st.n_dist,
        n_approx_comps=st.n_approx,
        n_hops=st.n_hops,
        final_l=st.l,
        saturated=st.saturated,
        n_encounters=st.n_enc,
    )
    if with_candidates:
        return res, st.ce_ids, jnp.sqrt(jnp.maximum(st.ce_d2, 0.0))
    return res


def error_bounded_probing_search(index: EMQGIndex, queries: jax.Array, k: int,
                                 alpha: float, l_max: int = 256,
                                 l_step: int = 1, max_hops: int = 4096,
                                 beam_width: int = 1, **kw) -> SearchResult:
    p = SearchParams(k=k, l0=k, l_max=l_max, l_step=l_step, alpha=alpha,
                     adaptive=True, max_hops=max_hops, beam_width=beam_width)
    return probing_search(index, queries, p, **kw)


# ---------------------------------------------------------------------------
# AGS — approximate greedy search (SymphonyQG), the δ-EMQG-AGS ablation:
# plain Algorithm-1 traversal guided purely by approximate distances, then a
# single exact rerank of the final candidate list.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("params",))
def ags_search(index: EMQGIndex, queries: jax.Array, params: SearchParams,
               start: Optional[jax.Array] = None) -> SearchResult:
    B = queries.shape[0]
    g, codes = index.graph, index.codes
    if start is None:
        start = jnp.broadcast_to(g.medoid, (B,)).astype(jnp.int32)
    exact_fn = make_exact_dist_fn(g.vectors)

    def one(q, s0):
        ctx = rabitq.prepare_query(codes, q)

        def approx_dist(q_, ids):
            return rabitq.estimate_sqdist(codes, ctx, ids)

        st, _ = _search_one(g.neighbors, approx_dist, q, s0, params,
                            faithful_prune=False)
        # exact rerank of the whole final buffer
        d2 = exact_fn(q, st.cand_ids)
        order = jnp.argsort(d2)
        return (st.cand_ids[order], d2[order], st.n_dist, st.n_enc,
                st.n_hops, st.l, st.saturated)

    ids, d2, n_approx, n_enc, hops, final_l, sat = jax.vmap(one)(queries, start)
    k = params.k
    return SearchResult(
        ids=ids[:, :k],
        dists=jnp.sqrt(jnp.maximum(d2[:, :k], 0.0)),
        n_dist_comps=jnp.full_like(n_approx, ids.shape[1]),  # rerank cost
        n_approx_comps=n_approx,
        n_hops=hops,
        final_l=final_l,
        saturated=sat,
        n_encounters=n_enc,
    )
