"""Algorithm 5 — Probing top-k ANN search on δ-EMQG.

Two-tier traversal: *expansion* walks the graph using RaBitQ approximate
distances (cheap, batched over a node's whole neighbor list); *probing*
promotes the best approximate candidate to the exact tier only when the
exact frontier has stopped improving.  The adaptive outer-``l`` loop and the
α stop rule are inherited from Algorithm 3 and apply to the exact tier.

Fixed-shape state (vmapped across the query batch, same discipline as
``search.py``):

  C_e — exact candidates  (ids, exact d², visited flags)   cap l_max+1
  C_a — approx candidates (ids, approx d², probed flags)   cap l_max+1
  T   — ring buffer of every id that ever entered either tier, for dedup

Also provides AGS (approximate greedy search + exact rerank — SymphonyQG's
search, the paper's δ-EMQG-AGS ablation).
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import rabitq
from .search import _merge_topc, make_exact_dist_fn
from .types import INVALID_ID, EMQGIndex, SearchParams, SearchResult


class _PState(NamedTuple):
    ce_ids: jax.Array
    ce_d2: jax.Array
    ce_vis: jax.Array
    ca_ids: jax.Array
    ca_d2: jax.Array
    ca_prb: jax.Array
    t_ids: jax.Array
    t_cnt: jax.Array
    d2_last: jax.Array
    l: jax.Array
    n_dist: jax.Array
    n_approx: jax.Array
    n_hops: jax.Array
    done: jax.Array
    saturated: jax.Array


def _probing_one(neighbors, exact_fn, approx_fn, q, ctx, start, p: SearchParams):
    C = p.l_max + 1
    M = neighbors.shape[1]
    T = 2 * p.max_hops  # both tiers feed the ring

    d2_s = exact_fn(q, start[None])[0]
    st = _PState(
        ce_ids=jnp.full((C,), INVALID_ID, jnp.int32).at[0].set(start),
        ce_d2=jnp.full((C,), jnp.inf, jnp.float32).at[0].set(d2_s),
        ce_vis=jnp.zeros((C,), jnp.bool_),
        ca_ids=jnp.full((C,), INVALID_ID, jnp.int32),
        ca_d2=jnp.full((C,), jnp.inf, jnp.float32),
        ca_prb=jnp.zeros((C,), jnp.bool_),
        t_ids=jnp.full((T,), INVALID_ID, jnp.int32).at[0].set(start),
        t_cnt=jnp.int32(1),
        d2_last=d2_s,
        l=jnp.int32(min(max(p.l0, p.k), p.l_max)),
        n_dist=jnp.int32(1),
        n_approx=jnp.int32(0),
        n_hops=jnp.int32(0),
        done=jnp.bool_(False),
        saturated=jnp.bool_(False),
    )
    pos = jnp.arange(C, dtype=jnp.int32)
    alpha2 = jnp.float32(p.alpha * p.alpha)

    def best_unvisited(ids, d2, vis, l):
        mask = (pos < l) & (ids >= 0) & (~vis)
        sel = jnp.argmin(jnp.where(mask, d2, jnp.inf))
        has = jnp.any(mask)
        return has, sel

    def cond(s: _PState):
        return (~s.done) & (s.n_hops < p.max_hops)

    def expand(s: _PState, sel_u) -> _PState:
        """Line 13-16: expand u with approximate distances into C_a."""
        u_id = s.ce_ids[sel_u]
        d2_u = s.ce_d2[sel_u]
        ce_vis = s.ce_vis.at[sel_u].set(True)
        nbrs = jnp.take(neighbors, jnp.maximum(u_id, 0), axis=0)
        valid = nbrs >= 0
        in_t = jnp.any(nbrs[:, None] == s.t_ids[None, :], axis=1)
        in_ca = jnp.any(nbrs[:, None] == s.ca_ids[None, :], axis=1)
        fresh = valid & ~in_t & ~in_ca
        d2a = approx_fn(ctx, jnp.where(fresh, nbrs, INVALID_ID))
        n_approx = s.n_approx + jnp.sum(fresh).astype(jnp.int32)
        ca_ids, ca_d2, ca_prb = _merge_topc(
            s.ca_ids, s.ca_d2, s.ca_prb,
            jnp.where(fresh, nbrs, INVALID_ID),
            jnp.where(fresh, d2a, jnp.inf),
            jnp.zeros_like(fresh), C,
        )
        return s._replace(ce_vis=ce_vis, ca_ids=ca_ids, ca_d2=ca_d2,
                          ca_prb=ca_prb, d2_last=d2_u, n_approx=n_approx,
                          n_hops=s.n_hops + 1)

    def probe(s: _PState, sel_w) -> _PState:
        """Line 9-11: compute the exact distance of w, promote to C_e."""
        w_id = s.ca_ids[sel_w]
        ca_prb = s.ca_prb.at[sel_w].set(True)
        t_ids = s.t_ids.at[s.t_cnt % T].set(w_id)
        t_cnt = s.t_cnt + 1
        d2_w = exact_fn(q, w_id[None])[0]
        one_id = jnp.full((1,), 0, jnp.int32).at[0].set(w_id)
        ce_ids, ce_d2, ce_vis = _merge_topc(
            s.ce_ids, s.ce_d2, s.ce_vis,
            one_id, d2_w[None], jnp.zeros((1,), jnp.bool_), C,
        )
        return s._replace(ce_ids=ce_ids, ce_d2=ce_d2, ce_vis=ce_vis,
                          ca_prb=ca_prb, t_ids=t_ids, t_cnt=t_cnt,
                          n_dist=s.n_dist + 1, n_hops=s.n_hops + 1)

    def converged(s: _PState) -> _PState:
        if not p.adaptive:
            return s._replace(done=jnp.bool_(True))
        d2_l = s.ce_d2[jnp.minimum(s.l - 1, C - 1)]
        d2_k = s.ce_d2[p.k - 1]
        stop = d2_l >= alpha2 * d2_k
        at_cap = s.l >= p.l_max
        return s._replace(
            l=jnp.where(stop, s.l, jnp.minimum(s.l + p.l_step, p.l_max)),
            done=stop | at_cap,
            saturated=s.saturated | (at_cap & ~stop),
        )

    def body(s: _PState) -> _PState:
        has_u, sel_u = best_unvisited(s.ce_ids, s.ce_d2, s.ce_vis, s.l)
        has_w, sel_w = best_unvisited(s.ca_ids, s.ca_d2, s.ca_prb, s.l)
        d2_u = jnp.where(has_u, s.ce_d2[sel_u], jnp.inf)
        d2_w = jnp.where(has_w, s.ca_d2[sel_w], jnp.inf)
        # NeedProbing (lines 22-28)
        need_probe = jnp.where(
            ~has_u,
            has_w,
            (d2_u > s.d2_last) & has_w & (d2_w < d2_u),
        )
        exhausted = ~has_u & ~has_w

        def do_converged(s):
            return converged(s)

        def do_step(s):
            return jax.lax.cond(
                need_probe, lambda s_: probe(s_, sel_w), lambda s_: expand(s_, sel_u), s
            )

        return jax.lax.cond(exhausted, do_converged, do_step, s)

    return jax.lax.while_loop(cond, body, st)


@partial(jax.jit, static_argnames=("params", "use_kernel", "with_candidates"))
def probing_search(
    index: EMQGIndex,
    queries: jax.Array,
    params: SearchParams,
    start: Optional[jax.Array] = None,
    use_kernel: bool = False,
    with_candidates: bool = False,
):
    """Batched Algorithm 5.  ``use_kernel`` routes the S₊ contraction through
    the Pallas bitdot kernel (interpret-mode on CPU)."""
    B = queries.shape[0]
    g, codes = index.graph, index.codes
    if start is None:
        start = jnp.broadcast_to(g.medoid, (B,)).astype(jnp.int32)
    exact_fn = make_exact_dist_fn(g.vectors)
    bitdot_fn = None
    if use_kernel:
        from repro.kernels.bitdot.ops import bitdot as bitdot_fn  # lazy: optional dep

    def approx_fn(ctx, ids):
        return rabitq.estimate_sqdist(codes, ctx, ids, bitdot_fn=bitdot_fn)

    def one(q, s0):
        ctx = rabitq.prepare_query(codes, q)
        return _probing_one(g.neighbors, exact_fn, approx_fn, q, ctx, s0, params)

    st = jax.vmap(one)(queries, start)
    k = params.k
    res = SearchResult(
        ids=st.ce_ids[:, :k],
        dists=jnp.sqrt(jnp.maximum(st.ce_d2[:, :k], 0.0)),
        n_dist_comps=st.n_dist,
        n_approx_comps=st.n_approx,
        n_hops=st.n_hops,
        final_l=st.l,
        saturated=st.saturated,
    )
    if with_candidates:
        return res, st.ce_ids, jnp.sqrt(jnp.maximum(st.ce_d2, 0.0))
    return res


def error_bounded_probing_search(index: EMQGIndex, queries: jax.Array, k: int,
                                 alpha: float, l_max: int = 256,
                                 l_step: int = 1, max_hops: int = 4096,
                                 **kw) -> SearchResult:
    p = SearchParams(k=k, l0=k, l_max=l_max, l_step=l_step, alpha=alpha,
                     adaptive=True, max_hops=max_hops)
    return probing_search(index, queries, p, **kw)


# ---------------------------------------------------------------------------
# AGS — approximate greedy search (SymphonyQG), the δ-EMQG-AGS ablation:
# plain Algorithm-1 traversal guided purely by approximate distances, then a
# single exact rerank of the final candidate list.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("params",))
def ags_search(index: EMQGIndex, queries: jax.Array, params: SearchParams,
               start: Optional[jax.Array] = None) -> SearchResult:
    from .search import _search_one  # same engine, approx dist plug

    B = queries.shape[0]
    g, codes = index.graph, index.codes
    if start is None:
        start = jnp.broadcast_to(g.medoid, (B,)).astype(jnp.int32)
    exact_fn = make_exact_dist_fn(g.vectors)

    def one(q, s0):
        ctx = rabitq.prepare_query(codes, q)

        def approx_dist(q_, ids):
            return rabitq.estimate_sqdist(codes, ctx, ids)

        st, _ = _search_one(g.neighbors, approx_dist, q, s0, params,
                            faithful_prune=False)
        # exact rerank of the whole final buffer
        d2 = exact_fn(q, st.cand_ids)
        order = jnp.argsort(d2)
        return (st.cand_ids[order], d2[order], st.n_dist, st.n_hops, st.l,
                st.saturated)

    ids, d2, n_approx, hops, final_l, sat = jax.vmap(one)(queries, start)
    k = params.k
    return SearchResult(
        ids=ids[:, :k],
        dists=jnp.sqrt(jnp.maximum(d2[:, :k], 0.0)),
        n_dist_comps=jnp.full_like(n_approx, ids.shape[1]),  # rerank cost
        n_approx_comps=n_approx,
        n_hops=hops,
        final_l=final_l,
        saturated=sat,
    )
