"""Algorithm 5 — Probing top-k ANN search on δ-EMQG.

Two-tier traversal: *expansion* walks the graph using RaBitQ approximate
distances (cheap, batched over a node's whole neighbor list); *probing*
promotes the best approximate candidate to the exact tier only when the
exact frontier has stopped improving.  The adaptive outer-``l`` loop and the
α stop rule are inherited from Algorithm 3 and apply to the exact tier.

``probing_search`` is the batch-level beam engine — the only Algorithm-5
engine in the repo.  One ``while_loop`` drives the whole batch; per iteration
each query either *probes* its ``beam_width`` best unprobed approximate
candidates (their exact distances are evaluated in one fused gather+L2 call
over ``[B, W]`` ids) or *expands* its W best unvisited exact candidates
(``B×W×M`` neighbor ids deduped against a packed visited bitset, approximate
distances in one batched RaBitQ estimate).  The NeedProbing rule
(lines 22-28) decides per query; finished queries are masked no-ops.

Fixed-shape state:

  C_e — exact candidates  (ids, exact d², visited flags)   cap l_max+1
  C_a — approx candidates (ids, approx d², probed flags)   cap l_max+1

Also provides AGS (approximate greedy search + exact rerank — SymphonyQG's
search, the paper's δ-EMQG-AGS ablation), built on the same batch engine:
the generic ``_beam_search_batch`` traversal runs with a RaBitQ approximate
``batch_dist``, then one fused exact gather+L2 call reranks the final
candidate buffers.

Correctness is checked against implementation-independent oracles — brute
force exact k-NN plus the paper's ``(1/δ)`` bound (``repro.testing.oracle``,
``tests/test_conformance.py``) — not a reference engine.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import rabitq
from .bitset import bitset_make, bitset_set, bitset_test, unique_per_row
from .search import (
    _beam_search_batch,
    adaptive_transition,
    batch_merge_topc,
    make_batch_dist_fn,
    resolve_beam_width,
    select_top_w,
)
from .types import INVALID_ID, EMQGIndex, SearchParams, SearchResult


# ---------------------------------------------------------------------------
# Batch-level beam engine.
# ---------------------------------------------------------------------------


class _BeamPState(NamedTuple):
    ce_ids: jax.Array      # int32[B, C]  exact tier
    ce_d2: jax.Array       # f32[B, C]
    ce_vis: jax.Array      # bool[B, C]
    ca_ids: jax.Array      # int32[B, C]  approx tier
    ca_d2: jax.Array       # f32[B, C]
    ca_prb: jax.Array      # bool[B, C]
    seen: jax.Array        # uint32[B, nw] every id that entered either tier
    d2_last: jax.Array     # f32[B]  exact d² of the last expanded node
    l: jax.Array           # int32[B]
    n_dist: jax.Array      # int32[B]
    n_approx: jax.Array    # int32[B]
    n_enc: jax.Array       # int32[B]  candidate encounters (pre-dedup)
    n_hops: jax.Array      # int32[B]
    done: jax.Array        # bool[B]
    saturated: jax.Array   # bool[B]


def _beam_probing_batch(
    neighbors: jax.Array,      # int32[n, M]
    n_nodes: int,
    batch_exact: Callable,     # (queries [B,d], ids [B,K]) → d2 [B,K]
    batch_approx: Callable,    # (ids [B,K]) → d2 [B,K]
    queries: jax.Array,
    start: jax.Array,
    p: SearchParams,
) -> _BeamPState:
    B = queries.shape[0]
    C = p.l_max + 1
    W = resolve_beam_width(p, C)
    M = neighbors.shape[1]

    pos = jnp.arange(C, dtype=jnp.int32)[None, :]
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]

    d2_s = batch_exact(queries, start[:, None])[:, 0]
    st = _BeamPState(
        ce_ids=jnp.full((B, C), INVALID_ID, jnp.int32).at[:, 0].set(start),
        ce_d2=jnp.full((B, C), jnp.inf, jnp.float32).at[:, 0].set(d2_s),
        ce_vis=jnp.zeros((B, C), jnp.bool_),
        ca_ids=jnp.full((B, C), INVALID_ID, jnp.int32),
        ca_d2=jnp.full((B, C), jnp.inf, jnp.float32),
        ca_prb=jnp.zeros((B, C), jnp.bool_),
        seen=bitset_set(bitset_make(B, n_nodes), start[:, None]),
        d2_last=d2_s,
        l=jnp.full((B,), min(max(p.l0, p.k), p.l_max), jnp.int32),
        n_dist=jnp.ones((B,), jnp.int32),
        n_approx=jnp.zeros((B,), jnp.int32),
        n_enc=jnp.ones((B,), jnp.int32),
        n_hops=jnp.zeros((B,), jnp.int32),
        done=jnp.zeros((B,), jnp.bool_),
        saturated=jnp.zeros((B,), jnp.bool_),
    )

    def active_mask(s: _BeamPState):
        return (~s.done) & (s.n_hops < p.max_hops)

    def cond(s: _BeamPState):
        return jnp.any(active_mask(s))

    def body(s: _BeamPState) -> _BeamPState:
        active = active_mask(s)
        win_e = (pos < s.l[:, None]) & (s.ce_ids >= 0) & (~s.ce_vis)
        win_e &= active[:, None]
        win_a = (pos < s.l[:, None]) & (s.ca_ids >= 0) & (~s.ca_prb)
        win_a &= active[:, None]
        has_u = jnp.any(win_e, axis=1)
        has_w = jnp.any(win_a, axis=1)
        d2_u = jnp.min(jnp.where(win_e, s.ce_d2, jnp.inf), axis=1)
        d2_w = jnp.min(jnp.where(win_a, s.ca_d2, jnp.inf), axis=1)

        # NeedProbing (lines 22-28): probe when the exact frontier stopped
        # improving and the approx tier has something closer.
        need_probe = jnp.where(
            ~has_u,
            has_w,
            (d2_u > s.d2_last) & has_w & (d2_w < d2_u),
        )
        probing = active & need_probe
        expanding = active & ~need_probe & has_u
        conv = active & ~has_u & ~has_w

        # -- probe branch: exact distances for W best unprobed approx --------
        sel_w, selv_w = select_top_w(s.ca_d2, win_a, W)
        selv_w &= probing[:, None]
        prb_sel = jnp.take_along_axis(s.ca_prb, sel_w, axis=1) | selv_w
        ca_prb = s.ca_prb.at[rows, sel_w].set(prb_sel)
        w_ids = jnp.where(
            selv_w, jnp.take_along_axis(s.ca_ids, sel_w, axis=1), INVALID_ID)
        d2_probe = batch_exact(queries, w_ids)                 # [B, W] fused
        n_dist = s.n_dist + jnp.sum(w_ids >= 0, axis=1).astype(jnp.int32)

        # -- expand branch: approx distances for W·M neighbor ids ------------
        sel_u, selv_u = select_top_w(s.ce_d2, win_e, W)
        selv_u &= expanding[:, None]
        vis_sel = jnp.take_along_axis(s.ce_vis, sel_u, axis=1) | selv_u
        ce_vis = s.ce_vis.at[rows, sel_u].set(vis_sel)
        u_ids = jnp.where(
            selv_u, jnp.take_along_axis(s.ce_ids, sel_u, axis=1), INVALID_ID)
        d2_u_sel = jnp.where(
            selv_u, jnp.take_along_axis(s.ce_d2, sel_u, axis=1), -jnp.inf)
        # "last expanded" = the worst of this hop's frontier (W=1: exactly u).
        d2_last = jnp.where(expanding, jnp.max(d2_u_sel, axis=1), s.d2_last)

        nbrs = jnp.take(neighbors, jnp.maximum(u_ids, 0), axis=0)
        nbrs = jnp.where(selv_u[:, :, None], nbrs, INVALID_ID).reshape(B, W * M)
        fresh = (nbrs >= 0) & ~bitset_test(s.seen, nbrs)
        new_ids = unique_per_row(nbrs, fresh)
        seen = bitset_set(s.seen, new_ids)
        d2a = batch_approx(new_ids)                            # [B, W·M]
        n_approx = s.n_approx + jnp.sum(new_ids >= 0, axis=1).astype(jnp.int32)
        # encounters: valid neighbor ids pre-dedup, plus probed candidates
        n_enc = s.n_enc + jnp.sum(nbrs >= 0, axis=1).astype(jnp.int32) \
            + jnp.sum(w_ids >= 0, axis=1).astype(jnp.int32)

        n_hops = s.n_hops + jnp.sum(selv_w, axis=1).astype(jnp.int32) \
            + jnp.sum(selv_u, axis=1).astype(jnp.int32)

        # -- merges (per query only one branch contributes real entries) -----
        ce_ids, ce_d2, ce_vis = batch_merge_topc(
            s.ce_ids, s.ce_d2, ce_vis,
            w_ids, d2_probe, jnp.zeros_like(w_ids, jnp.bool_), C)
        ca_ids, ca_d2, ca_prb = batch_merge_topc(
            s.ca_ids, s.ca_d2, ca_prb,
            new_ids, d2a, jnp.zeros_like(fresh), C)

        # -- adaptive transition for exhausted queries -----------------------
        l, done, saturated = adaptive_transition(
            p, ce_d2, s.l, s.done, s.saturated, conv)

        return _BeamPState(
            ce_ids=ce_ids, ce_d2=ce_d2, ce_vis=ce_vis,
            ca_ids=ca_ids, ca_d2=ca_d2, ca_prb=ca_prb,
            seen=seen, d2_last=d2_last, l=l, n_dist=n_dist,
            n_approx=n_approx, n_enc=n_enc, n_hops=n_hops, done=done,
            saturated=saturated)

    return jax.lax.while_loop(cond, body, st)


@partial(jax.jit, static_argnames=("params", "use_kernel", "with_candidates",
                                   "backend"))
def probing_search(
    index: EMQGIndex,
    queries: jax.Array,
    params: SearchParams,
    start: Optional[jax.Array] = None,
    use_kernel: bool = False,
    with_candidates: bool = False,
    backend: str = "auto",
):
    """Batched Algorithm 5 on the lock-step beam engine.  ``use_kernel``
    routes the S₊ contraction through the Pallas bitdot kernel
    (interpret-mode on CPU); ``backend`` selects the exact-tier gather+L2
    implementation (see ``make_batch_dist_fn``)."""
    B = queries.shape[0]
    g, codes = index.graph, index.codes
    if start is None:
        start = jnp.broadcast_to(g.medoid, (B,)).astype(jnp.int32)
    batch_exact = make_batch_dist_fn(g.vectors, backend)
    bitdot_fn = None
    if use_kernel:
        from repro.kernels.bitdot.ops import bitdot as bitdot_fn  # lazy: optional dep

    ctx = jax.vmap(lambda q: rabitq.prepare_query(codes, q))(queries)

    def batch_approx(ids):
        return jax.vmap(
            lambda c, i: rabitq.estimate_sqdist(codes, c, i, bitdot_fn=bitdot_fn)
        )(ctx, ids)

    st = _beam_probing_batch(g.neighbors, g.n, batch_exact, batch_approx,
                             queries, start, params)
    k = params.k
    res = SearchResult(
        ids=st.ce_ids[:, :k],
        dists=jnp.sqrt(jnp.maximum(st.ce_d2[:, :k], 0.0)),
        n_dist_comps=st.n_dist,
        n_approx_comps=st.n_approx,
        n_hops=st.n_hops,
        final_l=st.l,
        saturated=st.saturated,
        n_encounters=st.n_enc,
    )
    if with_candidates:
        return res, st.ce_ids, jnp.sqrt(jnp.maximum(st.ce_d2, 0.0))
    return res


def error_bounded_probing_search(index: EMQGIndex, queries: jax.Array, k: int,
                                 alpha: float, l_max: int = 256,
                                 l_step: int = 1, max_hops: int = 4096,
                                 beam_width: int = 1, **kw) -> SearchResult:
    p = SearchParams(k=k, l0=k, l_max=l_max, l_step=l_step, alpha=alpha,
                     adaptive=True, max_hops=max_hops, beam_width=beam_width)
    return probing_search(index, queries, p, **kw)


# ---------------------------------------------------------------------------
# AGS — approximate greedy search (SymphonyQG), the δ-EMQG-AGS ablation:
# plain Algorithm-1 traversal guided purely by approximate distances, then a
# single exact rerank of the final candidate list.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("params", "backend"))
def ags_search(index: EMQGIndex, queries: jax.Array, params: SearchParams,
               start: Optional[jax.Array] = None,
               backend: str = "auto") -> SearchResult:
    """Batched AGS on the lock-step beam engine.

    The generic ``_beam_search_batch`` traversal only consumes the graph
    topology and a ``batch_dist`` callable, so swapping in the RaBitQ
    estimator yields the approximate-guided frontier for free — the whole
    batch walks in one ``while_loop`` with the same bitset dedup and
    masked adaptive transitions as the exact engine.  The final candidate
    buffers (up to ``l_max+1`` ids per query) are then reranked with one
    fused exact gather+L2 call (``backend`` selects its implementation).

    Counters: ``n_approx_comps`` is the traversal's estimator evaluations;
    ``n_dist_comps`` is the exact rerank cost (valid buffer entries).
    """
    B = queries.shape[0]
    g, codes = index.graph, index.codes
    if start is None:
        start = jnp.broadcast_to(g.medoid, (B,)).astype(jnp.int32)

    ctx = jax.vmap(lambda q: rabitq.prepare_query(codes, q))(queries)

    def batch_approx(qs, ids):
        return jax.vmap(
            lambda c, i: rabitq.estimate_sqdist(codes, c, i))(ctx, ids)

    st = _beam_search_batch(g, queries, start, params, batch_approx)

    # exact rerank of the whole final buffer, one fused call
    batch_exact = make_batch_dist_fn(g.vectors, backend)
    d2 = batch_exact(queries, st.cand_ids)
    neg, order = jax.lax.top_k(-d2, d2.shape[1])
    ids = jnp.take_along_axis(st.cand_ids, order, axis=1)
    d2 = -neg
    k = params.k
    return SearchResult(
        ids=ids[:, :k],
        dists=jnp.sqrt(jnp.maximum(d2[:, :k], 0.0)),
        n_dist_comps=jnp.sum(st.cand_ids >= 0, axis=1).astype(jnp.int32),
        n_approx_comps=st.n_dist,
        n_hops=st.n_hops,
        final_l=st.l,
        saturated=st.saturated,
        n_encounters=st.n_enc,
    )
