"""Occlusion-region predicates and neighbor-selection rules.

This module is the geometric heart of the paper.  Every rule is expressed on
*squared* distances so the hot paths never take square roots except where a
rule is inherently metric (δ-EMG's cross term, τ-MG's additive shift) — there
we take the root once, outside any inner loop, on already-reduced scalars.

Rules implemented (all broadcastable / vmappable):

* ``occludes_delta``  — Def. 9 of the paper (δ-EMG occlusion region).
* ``occludes_mrng``   — MRNG lune (δ → 0 limit).
* ``occludes_vamana`` — DiskANN/Vamana robust-prune with slack α ≥ 1.
* ``occludes_taumg``  — τ-MG shifted lune.

and the sequential greedy selector ``select_neighbors`` that applies any rule
to a distance-sorted candidate list (Algorithm 2's ``SelectNeighbors`` and
Algorithm 4's ``LocallySelectNeighbors`` share it; the latter passes the
adaptive ``δ_t`` schedule from eq. (δ_t) of Sec. 6).
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from .types import INVALID_ID


# ---------------------------------------------------------------------------
# Occlusion predicates.  Arguments are *squared* distances:
#   d2_uv = d²(u, v)   candidate edge under test
#   d2_uw = d²(u, w)   kept (shorter) edge
#   d2_wv = d²(w, v)   kept-node-to-candidate distance
# Each returns True where w occludes v (edge (u, v) may be pruned).
# ---------------------------------------------------------------------------

def occludes_delta(d2_uv, d2_uw, d2_wv, delta):
    """Def. 9:  d(x,u) < d(u,v)  ∧  d²(x,v) + 2δ·d(u,v)·d(x,u) < d²(u,v).

    δ may be negative (Alg. 4's adaptive rule on long edges) — the region then
    *grows past* the MRNG lune, pruning more aggressively.  δ ∈ (0,1) shrinks
    it, keeping more edges (stronger guarantee).
    """
    d_uv = jnp.sqrt(d2_uv)
    d_uw = jnp.sqrt(d2_uw)
    return (d2_uw < d2_uv) & (d2_wv + 2.0 * delta * d_uv * d_uw < d2_uv)


def occludes_mrng(d2_uv, d2_uw, d2_wv, _unused=0.0):
    """MRNG lune: w strictly closer to both u and v than d(u,v)."""
    return (d2_uw < d2_uv) & (d2_wv < d2_uv)


def occludes_vamana(d2_uv, d2_uw, d2_wv, alpha=1.2):
    """Vamana robust prune: prune v if α·d(w,v) ≤ d(u,v) for a kept w."""
    return (d2_uw < d2_uv) & (alpha * alpha * d2_wv <= d2_uv)


def occludes_taumg(d2_uv, d2_uw, d2_wv, tau=0.1):
    """τ-MG shifted lune: prune v if d(u,w) < d(u,v) ∧ d(w,v) < d(u,v) − 3τ."""
    d_uv = jnp.sqrt(d2_uv)
    shifted = jnp.maximum(d_uv - 3.0 * tau, 0.0)
    return (d2_uw < d2_uv) & (d2_wv < shifted * shifted)


OCCLUSION_RULES: dict[str, Callable] = {
    "delta_emg": occludes_delta,
    "mrng": occludes_mrng,
    "vamana": occludes_vamana,
    "tau_mg": occludes_taumg,
}


# ---------------------------------------------------------------------------
# Navigable-ball membership (Lemma 1) — used by property tests.
# ---------------------------------------------------------------------------

def in_navigable_ball(q, u, v, delta):
    """True iff d(q, v) < δ·d(q, u): q lies in the ball where Lemma 1 bites."""
    d2_qv = jnp.sum((q - v) ** 2, axis=-1)
    d2_qu = jnp.sum((q - u) ** 2, axis=-1)
    return d2_qv < delta * delta * d2_qu


def in_occlusion_region(x, u, v, delta):
    """Point-level Def. 9 membership (tests / visual debugging)."""
    d2_xu = jnp.sum((x - u) ** 2, axis=-1)
    d2_xv = jnp.sum((x - v) ** 2, axis=-1)
    d2_uv = jnp.sum((u - v) ** 2, axis=-1)
    return occludes_delta(d2_uv, d2_xu, d2_xv, delta)


# ---------------------------------------------------------------------------
# Sequential greedy neighbor selection.
#
# Given candidates sorted by ascending distance from u, keep candidate v_i iff
# no already-kept w occludes it.  The loop over candidates is inherently
# sequential (each decision depends on the kept set) but each step is a fully
# vectorized check against the ≤ max_keep kept nodes; the whole function is
# vmapped over nodes by the builders.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("rule", "max_keep"))
def select_neighbors(
    u_vec: jax.Array,          # f32[d]      the node whose edges we pick
    cand_vecs: jax.Array,      # f32[L, d]   candidates, ascending d(u, ·)
    cand_d2: jax.Array,        # f32[L]      squared distances d²(u, c_i)
    cand_ids: jax.Array,       # int32[L]    global ids (INVALID_ID = padding)
    deltas: jax.Array,         # f32[L]      per-candidate rule parameter
    rule: str = "delta_emg",
    max_keep: int = 64,
) -> tuple[jax.Array, jax.Array]:
    """Returns (kept_ids int32[max_keep], kept_count int32).

    ``deltas[i]`` is the δ (or α / τ) used when testing whether candidate i is
    occluded — Algorithm 2 passes a constant vector, Algorithm 4 passes the
    adaptive schedule δ_t(u, v_i) = 1 − d(u,v_i)/d(u,v_(t)).
    """
    L, d = cand_vecs.shape
    occl = OCCLUSION_RULES[rule]

    kept_vecs0 = jnp.zeros((max_keep, d), cand_vecs.dtype)
    kept_d20 = jnp.full((max_keep,), jnp.inf, jnp.float32)
    kept_ids0 = jnp.full((max_keep,), INVALID_ID, jnp.int32)

    def body(i, state):
        kept_vecs, kept_d2, kept_ids, count = state
        v = cand_vecs[i]
        d2_uv = cand_d2[i]
        valid = (cand_ids[i] >= 0) & jnp.isfinite(d2_uv) & (d2_uv > 0.0)
        # distances kept-node → candidate (padding rows give +inf d2_uw → False)
        d2_wv = jnp.sum((kept_vecs - v[None, :]) ** 2, axis=-1)
        occluded = jnp.any(
            jnp.where(kept_ids >= 0, occl(d2_uv, kept_d2, d2_wv, deltas[i]), False)
        )
        take = valid & (~occluded) & (count < max_keep)
        slot = jnp.minimum(count, max_keep - 1)
        kept_vecs = jnp.where(take, kept_vecs.at[slot].set(v), kept_vecs)
        kept_d2 = jnp.where(take, kept_d2.at[slot].set(d2_uv), kept_d2)
        kept_ids = jnp.where(take, kept_ids.at[slot].set(cand_ids[i]), kept_ids)
        count = count + take.astype(jnp.int32)
        return kept_vecs, kept_d2, kept_ids, count

    _, _, kept_ids, count = jax.lax.fori_loop(
        0, L, body, (kept_vecs0, kept_d20, kept_ids0, jnp.int32(0))
    )
    return kept_ids, count


def adaptive_deltas(cand_d2: jax.Array, t: int) -> jax.Array:
    """Alg. 4's schedule  δ_t(u, v_i) = 1 − d(u, v_i) / d(u, v_(t)).

    ``cand_d2`` must be ascending;  v_(t) is the t-th closest (1-indexed).
    Negative on edges longer than d(u, v_(t)) — deliberately so (relaxed
    long-range pruning), see Sec. 6.
    """
    t_idx = jnp.clip(t - 1, 0, cand_d2.shape[0] - 1)
    d_t = jnp.sqrt(jnp.maximum(cand_d2[t_idx], 1e-30))
    return 1.0 - jnp.sqrt(cand_d2) / d_t
