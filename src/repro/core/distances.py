"""Distance primitives: blocked pairwise distances and exact brute-force kNN.

These are the *oracles* and construction workhorses.  The serving hot path
uses the Pallas kernels in ``repro.kernels`` (gather_l2 / bitdot); everything
here is plain XLA so it runs identically on CPU and TPU and is used to
validate the kernels.

Squared-distance identity used throughout:
    ‖x − y‖² = ‖x‖² + ‖y‖² − 2⟨x, y⟩
The ⟨x, y⟩ term is a matmul → lands on the MXU; the norm terms are rank-1
broadcasts.  We clamp at 0 to kill negative round-off.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def pairwise_sqdist(x: jax.Array, y: jax.Array) -> jax.Array:
    """f32[m, n] of squared distances between rows of x (m,d) and y (n,d)."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1)[:, None]
    y2 = jnp.sum(y * y, axis=-1)[None, :]
    xy = x @ y.T
    return jnp.maximum(x2 + y2 - 2.0 * xy, 0.0)


@jax.jit
def sqdist_one_to_many(q: jax.Array, ys: jax.Array) -> jax.Array:
    """f32[n] squared distances from a single query (d,) to rows of ys (n,d)."""
    diff = ys.astype(jnp.float32) - q.astype(jnp.float32)[None, :]
    return jnp.sum(diff * diff, axis=-1)


@partial(jax.jit, static_argnames=("k",))
def _knn_block(queries, base, k):
    d2 = pairwise_sqdist(queries, base)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx


def brute_force_knn(
    queries: jax.Array | np.ndarray,
    base: jax.Array | np.ndarray,
    k: int,
    block: int = 1024,
    exclude_self: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Exact top-k by blocked brute force.  Returns (dists f32[m,k], ids i32[m,k]).

    Distances returned are true (non-squared) Euclidean.  ``exclude_self``
    drops an exact-0 self match (construction convenience: queries == base).
    """
    base = jnp.asarray(base)
    kk = k + 1 if exclude_self else k
    out_d, out_i = [], []
    m = queries.shape[0]
    for s in range(0, m, block):
        qb = jnp.asarray(queries[s : s + block])
        d2, idx = _knn_block(qb, base, min(kk, base.shape[0]))
        d2, idx = np.asarray(d2), np.asarray(idx)
        if exclude_self:
            rows = np.arange(d2.shape[0]) + s
            self_pos = idx == rows[:, None]
            # push self matches to the end, then drop the last column
            d2 = np.where(self_pos, np.inf, d2)
            order = np.argsort(d2, axis=1, kind="stable")
            d2 = np.take_along_axis(d2, order, axis=1)[:, :k]
            idx = np.take_along_axis(idx, order, axis=1)[:, :k]
        out_d.append(np.sqrt(np.maximum(d2, 0.0)))
        out_i.append(idx.astype(np.int32))
    return np.concatenate(out_d), np.concatenate(out_i)


def medoid(vectors: jax.Array | np.ndarray, sample: int = 4096, seed: int = 0) -> int:
    """Approximate medoid: the dataset point nearest the (sampled) mean."""
    v = np.asarray(vectors)
    rng = np.random.default_rng(seed)
    if v.shape[0] > sample:
        idx = rng.choice(v.shape[0], sample, replace=False)
        mean = v[idx].mean(axis=0)
    else:
        mean = v.mean(axis=0)
    d2 = np.asarray(sqdist_one_to_many(jnp.asarray(mean), jnp.asarray(v)))
    return int(np.argmin(d2))
