"""Algorithm 4 — near-linear approximate δ-EMG construction.

Host-orchestrated, accelerator-bulk design (the same split DiskANN/Vamana
builders use): the O(n·L) beam searches and the O(n·L·M·d) occlusion pruning
run as vmapped JAX computations over node blocks; the cheap, irregular graph
surgery (reverse edges, connectivity repair) runs in NumPy between
iterations.  Each refinement iteration is idempotent given its input graph,
which is what makes the per-iteration checkpointing fault-tolerant: a
restarted worker redoes at most one iteration.

Faithful to the paper:
  * bootstrap = top-M approximate kNN graph           (line 2)
  * per-node candidates from greedy search            (line 6)
  * LocallySelectNeighbors with δ_t(u,v) = 1 − d(u,v)/d(u,v_(t))   (line 21)
  * degree cap M, reverse edges, connectivity repair  (lines 8–15)
  * optional degree alignment for δ-EMQG (binary search on t, Sec. 6.1)
"""

from __future__ import annotations

import dataclasses
import os
import time
from functools import partial
from typing import Optional


def _build_event(metrics, verbose: bool, phase: str, **fields) -> None:
    """Structured build progress: with a ``metrics`` registry the event is
    recorded (``build_progress`` ring entry + ``build_phase_seconds{phase}``
    histogram + ``build_nodes_total`` counter); ``verbose`` keeps the
    human-readable stderr-style line for CLI use.  Numbers come from the
    monotonic clock (``perf_counter``)."""
    if metrics is not None:
        metrics.event("build_progress", phase=phase, **fields)
        if "elapsed_s" in fields:
            metrics.histogram("build_phase_seconds",
                              {"phase": phase}).observe(fields["elapsed_s"])
        if "nodes" in fields:
            metrics.counter("build_nodes_total").inc(fields["nodes"])
    if verbose:
        body = " ".join(
            f"{k}={v:.1f}" if isinstance(v, float) else f"{k}={v}"
            for k, v in fields.items())
        print(f"[build_approx] {phase}: {body}")

import jax
import jax.numpy as jnp
import numpy as np

from .distances import brute_force_knn, medoid as find_medoid, pairwise_sqdist
from .geometry import adaptive_deltas, select_neighbors
from .search import SearchParams, search
from .types import GraphIndex


@dataclasses.dataclass(frozen=True)
class BuildParams:
    max_degree: int = 32          # M
    beam_width: int = 64          # L (candidate set size, paper uses 1000 at 1M scale)
    t: int = 16                   # neighborhood-scale parameter (t ≤ L)
    iters: int = 3                # refinement iterations I
    delta: Optional[float] = None  # None → adaptive δ_t rule; float → fixed δ (Exp-3)
    rule: str = "delta_emg"
    align_degree: bool = False    # δ-EMQG: binary-search t so |N(u)| == M exactly
    block: int = 512              # nodes per device batch
    max_hops: int = 1024
    seed: int = 0
    checkpoint_dir: Optional[str] = None


@partial(jax.jit, static_argnames=("rule", "max_keep", "fixed_delta", "t"))
def _select_block(vectors, u_ids, cand_ids, cand_dists, t, rule, max_keep,
                  fixed_delta):
    """Vectorized LocallySelectNeighbors over a block of nodes."""

    def one(u_id, ids, dists):
        u_vec = jnp.take(vectors, u_id, axis=0)
        d2 = jnp.where(ids >= 0, dists * dists, jnp.inf)
        vecs = jnp.take(vectors, jnp.maximum(ids, 0), axis=0)
        if fixed_delta is None:
            deltas = adaptive_deltas(d2, t)
        else:
            deltas = jnp.full(d2.shape, jnp.float32(fixed_delta))
        return select_neighbors(u_vec, vecs, d2, ids, deltas,
                                rule=rule, max_keep=max_keep)

    return jax.vmap(one)(u_ids, cand_ids, cand_dists)


@partial(jax.jit, static_argnames=("rule", "max_keep"))
def _select_block_per_node_t(vectors, u_ids, cand_ids, cand_dists, t_vec,
                             rule, max_keep):
    """Like _select_block but with a per-node t (degree-alignment search)."""

    def one(u_id, ids, dists, t):
        u_vec = jnp.take(vectors, u_id, axis=0)
        d2 = jnp.where(ids >= 0, dists * dists, jnp.inf)
        vecs = jnp.take(vectors, jnp.maximum(ids, 0), axis=0)
        t_idx = jnp.clip(t - 1, 0, d2.shape[0] - 1)
        d_t = jnp.sqrt(jnp.maximum(d2[t_idx], 1e-30))
        deltas = 1.0 - jnp.sqrt(d2) / d_t
        return select_neighbors(u_vec, vecs, d2, ids, deltas,
                                rule=rule, max_keep=max_keep)

    return jax.vmap(one)(u_ids, cand_ids, cand_dists, t_vec)


def _bfs_reachable(neighbors: np.ndarray, start: int) -> np.ndarray:
    """Frontier BFS over fixed-width adjacency.  bool[n]."""
    n = neighbors.shape[0]
    seen = np.zeros(n, bool)
    seen[start] = True
    frontier = np.array([start])
    while frontier.size:
        nxt = neighbors[frontier].ravel()
        nxt = nxt[nxt >= 0]
        nxt = np.unique(nxt)
        nxt = nxt[~seen[nxt]]
        seen[nxt] = True
        frontier = nxt
    return seen


def _add_reverse_edges(nbr: np.ndarray, deg: np.ndarray, M: int) -> None:
    """Line 14: add (v, u) for every (u, v), respecting the degree cap."""
    n = nbr.shape[0]
    src = np.repeat(np.arange(n, dtype=np.int32), nbr.shape[1])
    dst = nbr.ravel()
    ok = dst >= 0
    src, dst = src[ok], dst[ok]
    # iterate edges grouped by destination; numpy-side, O(E)
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    for u, v in zip(dst.tolist(), src.tolist()):  # add v into N(u)
        if deg[u] >= M:
            continue
        row = nbr[u, : deg[u]]
        if v == u or (row == v).any():
            continue
        nbr[u, deg[u]] = v
        deg[u] += 1


def _repair_connectivity(vectors_np: np.ndarray, nbr: np.ndarray,
                         deg: np.ndarray, M: int, med: int,
                         max_rounds: int = 8) -> int:
    """Line 15: link unreachable nodes from their nearest reachable node."""
    n = nbr.shape[0]
    total_fixed = 0
    for _ in range(max_rounds):
        seen = _bfs_reachable(nbr, med)
        bad = np.where(~seen)[0]
        if bad.size == 0:
            break
        good = np.where(seen)[0]
        gv = jnp.asarray(vectors_np[good])
        for s in range(0, bad.size, 1024):
            chunk = bad[s : s + 1024]
            d2 = pairwise_sqdist(jnp.asarray(vectors_np[chunk]), gv)
            nearest = good[np.asarray(jnp.argmin(d2, axis=1))]
            for x, r in zip(chunk.tolist(), nearest.tolist()):
                if deg[r] < M:
                    nbr[r, deg[r]] = x
                    deg[r] += 1
                else:
                    # replace r's longest out-edge (keeps the cap; the evicted
                    # edge is recoverable in the next refinement iteration)
                    row = nbr[r, :M]
                    d2row = ((vectors_np[row] - vectors_np[r]) ** 2).sum(-1)
                    nbr[r, int(np.argmax(d2row))] = x
                total_fixed += 1
    return total_fixed


def _candidate_search(graph: GraphIndex, queries: jax.Array, L: int,
                      max_hops: int):
    """Line 6: R_u ← GreedySearch(G, v_s, u, L, L), returning candidates."""
    p = SearchParams(k=min(L, graph.n), l0=L, l_max=L, adaptive=False,
                     max_hops=max_hops)
    _, cand_ids, cand_dists = search(graph, queries, p, with_candidates=True)
    return cand_ids, cand_dists


def _reverse_lists(nbr: np.ndarray, cap: int) -> np.ndarray:
    """int32[n, cap] of reverse neighbors (nodes pointing at each row)."""
    n, M = nbr.shape
    src = np.repeat(np.arange(n, dtype=np.int32), M)
    dst = nbr.ravel()
    ok = dst >= 0
    src, dst = src[ok], dst[ok]
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    out = np.full((n, cap), -1, np.int32)
    counts = np.zeros(n, np.int32)
    starts = np.searchsorted(dst, np.arange(n))
    ends = np.searchsorted(dst, np.arange(n) + 1)
    for u in range(n):
        take = src[starts[u] : ends[u]][:cap]
        out[u, : take.size] = take
        counts[u] = take.size
    return out


def _dedup_rows(ids: np.ndarray, self_ids: np.ndarray) -> np.ndarray:
    """Vectorized per-row dedup: later duplicates (and self) → -1."""
    order = np.argsort(ids, axis=1, kind="stable")
    s = np.take_along_axis(ids, order, axis=1)
    dup = np.zeros_like(s, bool)
    dup[:, 1:] = (s[:, 1:] == s[:, :-1]) & (s[:, 1:] >= 0)
    s = np.where(dup, -1, s)
    out = np.full_like(ids, -1)
    np.put_along_axis(out, order, s, axis=1)
    out[out == self_ids[:, None]] = -1
    return out


@partial(jax.jit, static_argnames=("L",))
def _prep_candidates(vectors, u_ids, merged_ids, L: int):
    """Exact d(u, ·) for merged candidate ids, sorted ascending, top L+1."""

    def one(u_id, ids):
        u_vec = jnp.take(vectors, u_id, axis=0)
        rows = jnp.take(vectors, jnp.maximum(ids, 0), axis=0)
        d2 = jnp.sum((rows - u_vec[None, :]) ** 2, axis=-1)
        d2 = jnp.where(ids >= 0, d2, jnp.inf)
        neg, idx = jax.lax.top_k(-d2, min(L + 1, ids.shape[0]))
        return ids[idx], jnp.sqrt(jnp.maximum(-neg, 0.0))

    return jax.vmap(one)(u_ids, merged_ids)


def _align_degrees(vectors, nbr, deg, cand_ids_all, cand_dists_all, p: BuildParams):
    """Sec. 6.1: binary-search the smallest t whose pruned neighborhood has
    ≥ M entries, then keep the M closest → every node has exactly M
    neighbors (FastScan / lane alignment)."""
    n, M, L = nbr.shape[0], p.max_degree, p.beam_width
    deficient = np.where(deg < M)[0]
    for s in range(0, deficient.size, p.block):
        idx = deficient[s : s + p.block]
        ids = jnp.asarray(cand_ids_all[idx])
        dst = jnp.asarray(cand_dists_all[idx])
        u_ids = jnp.asarray(idx.astype(np.int32))
        lo = np.full(idx.size, 1, np.int32)
        hi = np.full(idx.size, L, np.int32)
        n_cand = (cand_ids_all[idx] >= 0).sum(1)
        # nodes with fewer than M candidates can never reach M — take all
        feasible = n_cand >= M + 1
        best = hi.copy()
        for _ in range(int(np.ceil(np.log2(max(L, 2)))) + 1):
            mid = (lo + hi) // 2
            _, cnt = _select_block_per_node_t(
                vectors, u_ids, ids, dst, jnp.asarray(mid),
                rule=p.rule, max_keep=M + 1,
            )
            cnt = np.asarray(cnt)
            enough = cnt >= M
            best = np.where(enough & (mid < best), mid, best)
            hi = np.where(enough, np.maximum(mid - 1, 1), hi)
            lo = np.where(enough, lo, np.minimum(mid + 1, L))
            if (lo > hi).all():
                break
        t_final = np.where(feasible, best, L).astype(np.int32)
        kept, cnt = _select_block_per_node_t(
            vectors, u_ids, ids, dst, jnp.asarray(t_final),
            rule=p.rule, max_keep=M,
        )
        kept, cnt = np.array(kept), np.array(cnt)
        # pad any still-deficient rows with nearest unselected candidates
        ids_np = cand_ids_all[idx]
        for j in range(idx.size):
            row = kept[j]
            c = int(cnt[j])
            if c < M:
                pool = ids_np[j]
                pool = pool[(pool >= 0) & (pool != idx[j])]
                extra = [x for x in pool.tolist() if x not in set(row[:c].tolist())]
                take = extra[: M - c]
                row[c : c + len(take)] = take
                cnt[j] = c + len(take)
            nbr[idx[j]] = row
            deg[idx[j]] = cnt[j]


def build_approx(vectors, params: BuildParams = BuildParams(),
                 verbose: bool = False, metrics=None) -> GraphIndex:
    """Algorithm 4.  Returns a localized, degree-balanced approximate δ-EMG.

    ``metrics`` (an ``obs.MetricsRegistry``) receives structured build
    events per phase — bootstrap / refine iterations / degree alignment —
    with nodes/sec and elapsed time; ``verbose`` prints the same records
    for CLI use.  Observation-only: the built graph is identical either way.
    """
    p = params
    vectors = jnp.asarray(vectors, jnp.float32)
    vectors_np = np.asarray(vectors)
    n = vectors.shape[0]
    M, L = p.max_degree, min(p.beam_width, n)
    t_boot = time.perf_counter()
    med = find_medoid(vectors, seed=p.seed)

    # line 2: bootstrap from a top-M approximate NN graph
    _, knn_ids = brute_force_knn(vectors, vectors, min(M, n - 1),
                                 exclude_self=True)
    nbr = np.full((n, M), -1, np.int32)
    nbr[:, : knn_ids.shape[1]] = knn_ids
    graph = GraphIndex(vectors, jnp.asarray(nbr), jnp.int32(med),
                       kind="delta_emg_approx", delta=p.delta or 0.0)

    _build_event(metrics, verbose, "bootstrap", nodes=n,
                 elapsed_s=time.perf_counter() - t_boot,
                 nodes_per_s=n / max(time.perf_counter() - t_boot, 1e-9))

    cand_ids_all = np.full((n, L + 1), -1, np.int32)
    cand_dists_all = np.full((n, L + 1), np.inf, np.float32)

    for it in range(p.iters):
        t0 = time.perf_counter()
        new_nbr = np.full((n, M), -1, np.int32)
        new_deg = np.zeros(n, np.int32)
        # candidate enrichment: beam-search candidates ∪ current out-neighbors
        # ∪ reverse neighbors (the paper's reverse-edge step, applied at
        # candidate level — standard NSG/Vamana practice; without it the
        # search-only candidate sets of early iterations are anchored near
        # the medoid and clustered data loses inter-cluster navigability).
        cur_nbr = np.asarray(graph.neighbors)
        rev_nbr = _reverse_lists(cur_nbr, M)
        for s in range(0, n, p.block):
            ids_blk = np.arange(s, min(s + p.block, n), dtype=np.int32)
            q_blk = jnp.asarray(vectors_np[ids_blk])
            cand_ids, cand_dists = _candidate_search(graph, q_blk, L, p.max_hops)
            merged = np.concatenate(
                [np.asarray(cand_ids), cur_nbr[ids_blk], rev_nbr[ids_blk]],
                axis=1,
            )
            merged = _dedup_rows(merged, ids_blk)
            cand_ids, cand_dists = _prep_candidates(
                vectors, jnp.asarray(ids_blk), jnp.asarray(merged), L)
            kept, cnt = _select_block(
                vectors, jnp.asarray(ids_blk), cand_ids, cand_dists,
                t=min(p.t, L), rule=p.rule, max_keep=M,
                fixed_delta=p.delta,
            )
            new_nbr[ids_blk] = np.asarray(kept)
            new_deg[ids_blk] = np.asarray(cnt)
            if it == p.iters - 1:
                cand_ids_all[ids_blk] = np.asarray(cand_ids)
                cand_dists_all[ids_blk] = np.asarray(cand_dists)

        _add_reverse_edges(new_nbr, new_deg, M)
        n_fixed = _repair_connectivity(vectors_np, new_nbr, new_deg, M, med)
        graph = GraphIndex(vectors, jnp.asarray(new_nbr), jnp.int32(med),
                           kind="delta_emg_approx", delta=p.delta or 0.0)
        if p.checkpoint_dir:
            os.makedirs(p.checkpoint_dir, exist_ok=True)
            np.savez(os.path.join(p.checkpoint_dir, f"build_iter{it}.npz"),
                     neighbors=new_nbr, medoid=med, iter=it)
        elapsed = time.perf_counter() - t0
        _build_event(metrics, verbose, f"refine_iter{it}", nodes=n,
                     elapsed_s=elapsed, nodes_per_s=n / max(elapsed, 1e-9),
                     mean_deg=float((new_nbr >= 0).sum(1).mean()),
                     repaired=n_fixed)

    if p.align_degree:
        t0 = time.perf_counter()
        deg = (np.asarray(graph.neighbors) >= 0).sum(1).astype(np.int32)
        nbr = np.asarray(graph.neighbors).copy()
        _align_degrees(vectors, nbr, deg, cand_ids_all, cand_dists_all, p)
        _repair_connectivity(vectors_np, nbr, deg, M, med)
        graph = GraphIndex(vectors, jnp.asarray(nbr), jnp.int32(med),
                           kind="delta_emqg", delta=p.delta or 0.0)
        elapsed = time.perf_counter() - t0
        _build_event(metrics, verbose, "align_degree", nodes=n,
                     elapsed_s=elapsed, nodes_per_s=n / max(elapsed, 1e-9))
    return graph
