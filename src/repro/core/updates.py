"""Streaming index maintenance: insert / delete on a live δ-EMG
(FreshDiskANN-style), without full rebuilds.

Insert (batched): search the current graph for each new point's
neighborhood (the same candidate generation as Algorithm 4), prune with the
adaptive occlusion rule, splice the new rows into the fixed-width adjacency,
and add reverse edges under the degree cap.  The δ-EMG closure is restored
*locally* — exactly the per-node operation one refinement iteration of
Algorithm 4 performs, so quality matches a rebuilt graph up to the usual
approximate-construction gap (tested).

Delete (lazy + consolidate): deletions mark a tombstone bitmap consulted by
``search_live`` (results filter tombstones; traversal still routes through
them, preserving connectivity — the FreshDiskANN insight).  When tombstones
exceed ``consolidate_frac``, ``consolidate`` splices each deleted node out
by locally reconnecting its in-neighbors to its out-neighbors under the
occlusion rule, then compacts.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .build_approx import BuildParams, _prep_candidates, _select_block
from .distances import medoid as find_medoid
from .search import SearchParams, search
from .types import GraphIndex, SearchResult


@dataclasses.dataclass
class LiveIndex:
    """A δ-EMG plus mutation state (host-managed, device-resident arrays)."""

    graph: GraphIndex
    tombstones: np.ndarray            # bool[n]
    params: BuildParams

    @property
    def n_live(self) -> int:
        return int((~self.tombstones).sum())

    @property
    def frac_deleted(self) -> float:
        return float(self.tombstones.mean())


def as_live(graph: GraphIndex, params: Optional[BuildParams] = None) -> LiveIndex:
    return LiveIndex(graph=graph,
                     tombstones=np.zeros(graph.n, bool),
                     params=params or BuildParams())


def insert(live: LiveIndex, new_vectors: np.ndarray) -> LiveIndex:
    """Batched insertion.  Returns a new LiveIndex (functional host state)."""
    p = live.params
    g = live.graph
    vec_np = np.asarray(g.vectors)
    new_vectors = np.asarray(new_vectors, np.float32)
    m = new_vectors.shape[0]
    n0 = g.n
    M = g.max_degree
    L = min(p.beam_width, n0)

    # candidate generation on the current graph
    sp = SearchParams(k=min(L, n0), l0=L, l_max=L, adaptive=False,
                      max_hops=p.max_hops)
    _, cand_ids, cand_dists = search(g, jnp.asarray(new_vectors), sp,
                                     with_candidates=True)

    all_vecs = np.concatenate([vec_np, new_vectors])
    vectors = jnp.asarray(all_vecs)
    new_ids = jnp.arange(n0, n0 + m, dtype=jnp.int32)
    kept, cnt = _select_block(
        vectors, new_ids, cand_ids, cand_dists,
        t=min(p.t, L), rule=p.rule, max_keep=M, fixed_delta=p.delta)
    kept, cnt = np.array(kept), np.array(cnt)

    nbr = np.concatenate([np.asarray(g.neighbors),
                          np.full((m, M), -1, np.int32)])
    deg = (nbr >= 0).sum(1).astype(np.int32)
    nbr[n0:] = kept
    deg[n0:] = cnt

    # reverse edges under the cap; replace the longest edge when full so new
    # nodes always become reachable (same rule as connectivity repair)
    for j in range(m):
        u = n0 + j
        for v in kept[j, : cnt[j]].tolist():
            row = nbr[v, : deg[v]]
            if (row == u).any():
                continue
            if deg[v] < M:
                nbr[v, deg[v]] = u
                deg[v] += 1
            else:
                d2row = ((all_vecs[nbr[v, :M]] - all_vecs[v]) ** 2).sum(-1)
                worst = int(np.argmax(d2row))
                if d2row[worst] > ((all_vecs[u] - all_vecs[v]) ** 2).sum():
                    nbr[v, worst] = u

    graph = GraphIndex(vectors=vectors, neighbors=jnp.asarray(nbr),
                       medoid=g.medoid, kind=g.kind, delta=g.delta)
    tomb = np.concatenate([live.tombstones, np.zeros(m, bool)])
    return LiveIndex(graph=graph, tombstones=tomb, params=p)


def delete(live: LiveIndex, ids) -> LiveIndex:
    tomb = live.tombstones.copy()
    tomb[np.asarray(ids)] = True
    return LiveIndex(graph=live.graph, tombstones=tomb, params=live.params)


def search_live(live: LiveIndex, queries, k: int, alpha: float = 1.2,
                l_max: int = 128, **kw) -> SearchResult:
    """Error-bounded search that filters tombstones from the results while
    still routing through them.  Over-fetches k + #tombstone-margin."""
    over = int(min(l_max, k + max(8, 4 * int(live.tombstones.sum() > 0) * k)))
    p = SearchParams(k=over, l0=over, l_max=l_max, alpha=alpha,
                     adaptive=True, max_hops=kw.pop("max_hops", 2048))
    res = search(live.graph, jnp.asarray(queries), p, **kw)
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    out_ids = np.full((ids.shape[0], k), -1, np.int32)
    out_d = np.full((ids.shape[0], k), np.inf, np.float32)
    for b in range(ids.shape[0]):
        keep = [(d, i) for d, i in zip(dists[b], ids[b])
                if i >= 0 and not live.tombstones[i]][:k]
        for j, (d, i) in enumerate(keep):
            out_ids[b, j] = i
            out_d[b, j] = d
    return SearchResult(ids=jnp.asarray(out_ids), dists=jnp.asarray(out_d),
                        n_dist_comps=res.n_dist_comps,
                        n_approx_comps=res.n_approx_comps,
                        n_hops=res.n_hops, final_l=res.final_l,
                        saturated=res.saturated)


def consolidate(live: LiveIndex) -> LiveIndex:
    """Splice tombstoned nodes out: reconnect in-neighbors to the deleted
    node's out-neighbors (occlusion-pruned), then compact ids."""
    p = live.params
    g = live.graph
    vec_np = np.asarray(g.vectors)
    nbr = np.asarray(g.neighbors).copy()
    tomb = live.tombstones
    n, M = nbr.shape
    dead = set(np.where(tomb)[0].tolist())
    if not dead:
        return live

    # in-neighbor lists of dead nodes
    in_of_dead: dict[int, list[int]] = {d: [] for d in dead}
    for u in range(n):
        if u in dead:
            continue
        for v in nbr[u]:
            if v >= 0 and int(v) in dead:
                in_of_dead[int(v)].append(u)

    vectors = g.vectors
    touched = set()
    for d, in_nbrs in in_of_dead.items():
        repl = [int(x) for x in nbr[d] if x >= 0 and int(x) not in dead]
        for u in in_nbrs:
            row = [int(x) for x in nbr[u] if x >= 0 and int(x) not in dead]
            merged = np.asarray(sorted(set(row + repl) - {u}), np.int64)
            if merged.size == 0:
                continue
            ids = jnp.asarray(np.pad(merged, (0, max(0, 2 * M - merged.size)),
                                     constant_values=-1)[: 2 * M].astype(np.int32))
            d2 = np.linalg.norm(vec_np[np.maximum(np.asarray(ids), 0)]
                                - vec_np[u], axis=1)
            cand_ids, cand_dists = _prep_candidates(
                vectors, jnp.asarray([u], jnp.int32), ids[None], 2 * M - 1)
            kept, cnt = _select_block(
                vectors, jnp.asarray([u], jnp.int32), cand_ids, cand_dists,
                t=min(p.t, 2 * M - 1), rule=p.rule, max_keep=M,
                fixed_delta=p.delta)
            nbr[u] = np.array(kept)[0]
            touched.add(u)

    # compact: drop dead rows, remap ids
    alive = np.where(~tomb)[0]
    remap = -np.ones(n, np.int64)
    remap[alive] = np.arange(alive.size)
    new_nbr = nbr[alive]
    valid = new_nbr >= 0
    new_nbr = np.where(valid, remap[np.maximum(new_nbr, 0)], -1).astype(np.int32)
    new_nbr[new_nbr == -1] = -1
    new_vec = vec_np[alive]
    med = find_medoid(new_vec)
    graph = GraphIndex(vectors=jnp.asarray(new_vec),
                       neighbors=jnp.asarray(new_nbr),
                       medoid=jnp.int32(med), kind=g.kind, delta=g.delta)
    return LiveIndex(graph=graph, tombstones=np.zeros(alive.size, bool),
                     params=p)
