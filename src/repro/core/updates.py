"""Streaming index maintenance: insert / delete on a live δ-EMG
(FreshDiskANN-style), without full rebuilds.

Insert (batched): search the current graph for each new point's
neighborhood (the same candidate generation as Algorithm 4), prune with the
adaptive occlusion rule, splice the new rows into the fixed-width adjacency,
and add reverse edges under the degree cap.  The δ-EMG closure is restored
*locally* — exactly the per-node operation one refinement iteration of
Algorithm 4 performs, so quality matches a rebuilt graph up to the usual
approximate-construction gap (tested).

Delete (lazy + consolidate): deletions mark a tombstone bitmap consulted by
``search_live`` (results filter tombstones; traversal still routes through
them, preserving connectivity — the FreshDiskANN insight).  When tombstones
exceed ``consolidate_frac``, ``consolidate`` splices each deleted node out
by locally reconnecting its in-neighbors to its out-neighbors under the
occlusion rule, then compacts.

Crash safety (``JournaledLiveIndex``): every mutation batch is journaled to
a write-ahead log *before* it touches the in-memory ``LiveIndex``.  A WAL
record is two files committed in order — ``wal_XXXXXXXXX.npz`` (payload
arrays) then ``wal_XXXXXXXXX.json`` (manifest: seq, op, per-array CRC32,
the same integrity conventions as ``checkpoint/manager.py``) — each written
via tmp + ``os.replace``.  A record is committed iff its manifest exists,
parses, and every checksum matches; a crash mid-append leaves a torn
(manifest-less or checksum-failing) record that recovery treats as
never-written.  Periodic full checkpoints (``checkpoint()``) bound replay
length; ``recover()`` restores the newest intact checkpoint (corrupt steps
are walked back, courtesy of the manager) and replays committed WAL
records in sequence.  Because every op is a deterministic function of
(state, payload), recovery reproduces the uninterrupted run bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import re
import time
import zlib
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import list_steps, restore_latest, save_checkpoint

from .build_approx import (BuildParams, _prep_candidates,
                           _repair_connectivity, _select_block)
from .distances import medoid as find_medoid
from .search import SearchParams, search
from .types import GraphIndex, SearchResult

log = logging.getLogger("repro.updates")


@dataclasses.dataclass
class LiveIndex:
    """A δ-EMG plus mutation state (host-managed, device-resident arrays)."""

    graph: GraphIndex
    tombstones: np.ndarray            # bool[n]
    params: BuildParams

    @property
    def n_live(self) -> int:
        return int((~self.tombstones).sum())

    @property
    def frac_deleted(self) -> float:
        return float(self.tombstones.mean())


def as_live(graph: GraphIndex, params: Optional[BuildParams] = None) -> LiveIndex:
    return LiveIndex(graph=graph,
                     tombstones=np.zeros(graph.n, bool),
                     params=params or BuildParams())


def insert(live: LiveIndex, new_vectors: np.ndarray,
           fault_hook: Optional[Callable[[str], None]] = None) -> LiveIndex:
    """Batched insertion.  Returns a new LiveIndex (functional host state).

    ``fault_hook`` (testing only) is called at the ``mid_splice`` point —
    after the new rows are spliced into the adjacency but before reverse
    edges restore the local δ-closure; a hook that raises simulates a crash
    that leaves a half-mutated adjacency on the floor."""
    p = live.params
    g = live.graph
    vec_np = np.asarray(g.vectors)
    new_vectors = np.asarray(new_vectors, np.float32)
    m = new_vectors.shape[0]
    n0 = g.n
    M = g.max_degree
    L = min(p.beam_width, n0)

    # candidate generation on the current graph
    sp = SearchParams(k=min(L, n0), l0=L, l_max=L, adaptive=False,
                      max_hops=p.max_hops)
    _, cand_ids, cand_dists = search(g, jnp.asarray(new_vectors), sp,
                                     with_candidates=True)

    all_vecs = np.concatenate([vec_np, new_vectors])
    vectors = jnp.asarray(all_vecs)
    new_ids = jnp.arange(n0, n0 + m, dtype=jnp.int32)
    kept, cnt = _select_block(
        vectors, new_ids, cand_ids, cand_dists,
        t=min(p.t, L), rule=p.rule, max_keep=M, fixed_delta=p.delta)
    kept, cnt = np.array(kept), np.array(cnt)

    nbr = np.concatenate([np.asarray(g.neighbors),
                          np.full((m, M), -1, np.int32)])
    deg = (nbr >= 0).sum(1).astype(np.int32)
    nbr[n0:] = kept
    deg[n0:] = cnt
    if fault_hook is not None:
        fault_hook("mid_splice")

    # reverse edges under the cap; replace the longest edge when full so new
    # nodes always become reachable (same rule as connectivity repair)
    for j in range(m):
        u = n0 + j
        for v in kept[j, : cnt[j]].tolist():
            row = nbr[v, : deg[v]]
            if (row == u).any():
                continue
            if deg[v] < M:
                nbr[v, deg[v]] = u
                deg[v] += 1
            else:
                d2row = ((all_vecs[nbr[v, :M]] - all_vecs[v]) ** 2).sum(-1)
                worst = int(np.argmax(d2row))
                if d2row[worst] > ((all_vecs[u] - all_vecs[v]) ** 2).sum():
                    nbr[v, worst] = u

    # evicting a full row's longest edge above can sever some node's only
    # in-edge — run the builder's connectivity repair so every node stays
    # reachable from the medoid (deterministic, so WAL replay reproduces it)
    deg = (nbr >= 0).sum(1).astype(np.int32)
    _repair_connectivity(all_vecs, nbr, deg, M, int(np.asarray(g.medoid)))

    graph = GraphIndex(vectors=vectors, neighbors=jnp.asarray(nbr),
                       medoid=g.medoid, kind=g.kind, delta=g.delta)
    tomb = np.concatenate([live.tombstones, np.zeros(m, bool)])
    return LiveIndex(graph=graph, tombstones=tomb, params=p)


def delete(live: LiveIndex, ids) -> LiveIndex:
    tomb = live.tombstones.copy()
    tomb[np.asarray(ids)] = True
    return LiveIndex(graph=live.graph, tombstones=tomb, params=live.params)


def search_live(live: LiveIndex, queries, k: int, alpha: float = 1.2,
                l_max: int = 128, **kw) -> SearchResult:
    """Error-bounded search that filters tombstones from the results while
    still routing through them.  Over-fetches k + #tombstone-margin."""
    over = int(min(l_max, k + max(8, 4 * int(live.tombstones.sum() > 0) * k)))
    p = SearchParams(k=over, l0=over, l_max=l_max, alpha=alpha,
                     adaptive=True, max_hops=kw.pop("max_hops", 2048))
    res = search(live.graph, jnp.asarray(queries), p, **kw)
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    out_ids = np.full((ids.shape[0], k), -1, np.int32)
    out_d = np.full((ids.shape[0], k), np.inf, np.float32)
    for b in range(ids.shape[0]):
        keep = [(d, i) for d, i in zip(dists[b], ids[b])
                if i >= 0 and not live.tombstones[i]][:k]
        for j, (d, i) in enumerate(keep):
            out_ids[b, j] = i
            out_d[b, j] = d
    return SearchResult(ids=jnp.asarray(out_ids), dists=jnp.asarray(out_d),
                        n_dist_comps=res.n_dist_comps,
                        n_approx_comps=res.n_approx_comps,
                        n_hops=res.n_hops, final_l=res.final_l,
                        saturated=res.saturated,
                        n_encounters=res.n_encounters)


def consolidate(live: LiveIndex) -> LiveIndex:
    """Splice tombstoned nodes out: reconnect in-neighbors to the deleted
    node's out-neighbors (occlusion-pruned), then compact ids."""
    p = live.params
    g = live.graph
    vec_np = np.asarray(g.vectors)
    nbr = np.asarray(g.neighbors).copy()
    tomb = live.tombstones
    n, M = nbr.shape
    dead = set(np.where(tomb)[0].tolist())
    if not dead:
        return live

    # in-neighbor lists of dead nodes
    in_of_dead: dict[int, list[int]] = {d: [] for d in dead}
    for u in range(n):
        if u in dead:
            continue
        for v in nbr[u]:
            if v >= 0 and int(v) in dead:
                in_of_dead[int(v)].append(u)

    vectors = g.vectors
    touched = set()
    for d, in_nbrs in in_of_dead.items():
        repl = [int(x) for x in nbr[d] if x >= 0 and int(x) not in dead]
        for u in in_nbrs:
            row = [int(x) for x in nbr[u] if x >= 0 and int(x) not in dead]
            merged = np.asarray(sorted(set(row + repl) - {u}), np.int64)
            if merged.size == 0:
                continue
            ids = jnp.asarray(np.pad(merged, (0, max(0, 2 * M - merged.size)),
                                     constant_values=-1)[: 2 * M].astype(np.int32))
            d2 = np.linalg.norm(vec_np[np.maximum(np.asarray(ids), 0)]
                                - vec_np[u], axis=1)
            cand_ids, cand_dists = _prep_candidates(
                vectors, jnp.asarray([u], jnp.int32), ids[None], 2 * M - 1)
            kept, cnt = _select_block(
                vectors, jnp.asarray([u], jnp.int32), cand_ids, cand_dists,
                t=min(p.t, 2 * M - 1), rule=p.rule, max_keep=M,
                fixed_delta=p.delta)
            nbr[u] = np.array(kept)[0]
            touched.add(u)

    # compact: drop dead rows, remap ids
    alive = np.where(~tomb)[0]
    remap = -np.ones(n, np.int64)
    remap[alive] = np.arange(alive.size)
    new_nbr = nbr[alive]
    valid = new_nbr >= 0
    new_nbr = np.where(valid, remap[np.maximum(new_nbr, 0)], -1).astype(np.int32)
    new_nbr[new_nbr == -1] = -1
    new_vec = vec_np[alive]
    med = find_medoid(new_vec)
    graph = GraphIndex(vectors=jnp.asarray(new_vec),
                       neighbors=jnp.asarray(new_nbr),
                       medoid=jnp.int32(med), kind=g.kind, delta=g.delta)
    return LiveIndex(graph=graph, tombstones=np.zeros(alive.size, bool),
                     params=p)


# ---------------------------------------------------------------------------
# Write-ahead log + crash-safe journaled index (module docstring, part 2).
# ---------------------------------------------------------------------------

_WAL_RE = re.compile(r"^wal_(\d{9})\.json$")


class WalCorruptError(RuntimeError):
    """A WAL record failed integrity checks (treated as never-written)."""


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _atomic_write(path: str, data: bytes,
                  fsync_hist=None) -> None:
    """tmp + fsync + rename.  ``fsync_hist`` (an ``obs.Histogram``) times
    the fsync alone — on real disks that is where WAL commit latency lives,
    and it is the number a "why did p99 spike" investigation needs split
    from serialization cost."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        if fsync_hist is not None:
            t0 = time.perf_counter()
            os.fsync(f.fileno())
            fsync_hist.observe(time.perf_counter() - t0)
        else:
            os.fsync(f.fileno())
    os.replace(tmp, path)


def wal_append(wal_dir: str, seq: int, op: str,
               payload: dict[str, np.ndarray],
               fault_hook: Optional[Callable[[str], None]] = None,
               metrics=None, compress: bool = False) -> str:
    """Append one committed record.  Payload npz lands first, the manifest
    (whose existence *is* the commit) second — a crash between the two
    (the ``torn_journal`` fault point) leaves an uncommitted torn record.

    ``compress`` writes the payload with ``np.savez_compressed`` — the
    manifest checksums the *arrays*, not the file, so compressed and plain
    records verify and replay identically (``wal_read`` is format-blind).

    ``metrics`` (an ``obs.MetricsRegistry``) times the whole append into
    ``wal_append_seconds``, each fsync into ``wal_fsync_seconds``, and
    counts ``wal_records_total{op}``."""
    t_start = time.perf_counter()
    fsync_hist = None if metrics is None else \
        metrics.histogram("wal_fsync_seconds")
    os.makedirs(wal_dir, exist_ok=True)
    base = os.path.join(wal_dir, f"wal_{seq:09d}")
    import io
    buf = io.BytesIO()
    (np.savez_compressed if compress else np.savez)(buf, **payload)
    _atomic_write(base + ".npz", buf.getvalue(), fsync_hist=fsync_hist)
    if fault_hook is not None:
        fault_hook("torn_journal")
    manifest = {
        "seq": seq,
        "op": op,
        "keys": sorted(payload.keys()),
        "dtypes": {k: str(v.dtype) for k, v in payload.items()},
        "shapes": {k: list(v.shape) for k, v in payload.items()},
        "checksums": {k: _crc(v) for k, v in payload.items()},
    }
    _atomic_write(base + ".json", json.dumps(manifest).encode(),
                  fsync_hist=fsync_hist)
    if metrics is not None:
        metrics.histogram("wal_append_seconds").observe(
            time.perf_counter() - t_start)
        metrics.counter("wal_records_total", {"op": op}).inc()
    return base + ".json"


def wal_read(wal_dir: str, seq: int) -> tuple[str, dict[str, np.ndarray]]:
    """Load + verify one record.  Raises ``WalCorruptError`` on any
    integrity violation (missing/torn manifest, unreadable npz, checksum
    mismatch) — recovery treats those records as never-written."""
    base = os.path.join(wal_dir, f"wal_{seq:09d}")
    if not os.path.exists(base + ".json") and not os.path.exists(base + ".npz"):
        raise FileNotFoundError(f"no WAL record {seq}")   # clean end of log
    try:
        with open(base + ".json") as f:
            manifest = json.load(f)
    except Exception as e:
        # payload present but manifest missing/unparsable: torn record
        raise WalCorruptError(f"record {seq}: unreadable manifest: {e}") from e
    try:
        with np.load(base + ".npz") as z:
            payload = {k: z[k].copy() for k in z.files}
    except Exception as e:
        raise WalCorruptError(f"record {seq}: unreadable payload: {e}") from e
    if set(manifest.get("keys", [])) != set(payload.keys()):
        raise WalCorruptError(f"record {seq}: manifest/payload key mismatch")
    for k, arr in payload.items():
        want = manifest["checksums"].get(k)
        if want is not None and _crc(arr) != want:
            raise WalCorruptError(f"record {seq}: checksum mismatch on {k!r}")
    return manifest["op"], payload


def wal_seqs(wal_dir: str) -> list[int]:
    """Sequence numbers of records with a manifest present (not verified)."""
    if not os.path.isdir(wal_dir):
        return []
    return sorted(int(m.group(1))
                  for m in map(_WAL_RE.match, os.listdir(wal_dir)) if m)


def _record_bytes(wal_dir: str, seq: int) -> int:
    """On-disk footprint of one record (payload + manifest; 0 if absent)."""
    base = os.path.join(wal_dir, f"wal_{seq:09d}")
    total = 0
    for suffix in (".npz", ".json"):
        try:
            total += os.path.getsize(base + suffix)
        except OSError:
            pass
    return total


def _truncate_wal(wal_dir: str, upto_seq: int) -> None:
    for s in wal_seqs(wal_dir):
        if s <= upto_seq:
            base = os.path.join(wal_dir, f"wal_{s:09d}")
            for suffix in (".json", ".npz"):
                try:
                    os.remove(base + suffix)
                except FileNotFoundError:
                    pass


def _apply_op(live: LiveIndex, op: str, payload: dict,
              fault_hook=None) -> LiveIndex:
    """Deterministic op application — shared by the live path and replay."""
    if op == "insert":
        return insert(live, payload["vectors"], fault_hook=fault_hook)
    if op == "delete":
        return delete(live, payload["ids"])
    if op == "consolidate":
        return consolidate(live)
    raise ValueError(f"unknown WAL op: {op!r}")


class JournaledLiveIndex:
    """A ``LiveIndex`` whose mutations are crash-safe (WAL + checkpoints).

    Layout under ``directory``::

        meta.json            static state (BuildParams, kind, δ) — written once
        ckpt/step_XXXXXXXXX/ full snapshots via ``checkpoint.manager``
                             (step number == WAL sequence at save time)
        wal/wal_XXXXXXXXX.{npz,json}   journal records (seq 1, 2, ...)

    ``fault_hook(point)`` (testing only) is invoked at the named crash
    points — ``before_journal``, ``torn_journal``, ``after_journal``,
    ``mid_splice`` — with the convention that a raising hook simulates the
    process dying there; the on-disk state is whatever the protocol had
    durably committed by that point.

    ``consolidate_frac``: when a delete pushes the tombstone fraction past
    this threshold, a ``consolidate`` is triggered automatically — and
    journaled as its own record, so replay re-runs it at the same position
    in the op stream.

    ``checkpoint_every_bytes``: when the WAL grows past this many bytes
    since the last checkpoint (measured as on-disk record footprint — the
    quantity that actually bounds recovery replay I/O, unlike an op count,
    which a single large insert batch defeats), a checkpoint is taken
    automatically right after the mutation commits.  ``compress`` writes
    WAL payloads with ``np.savez_compressed``; both knobs are persisted in
    ``meta.json`` so ``recover()`` restores them (and the byte accumulator)
    and stays bit-identical either way.
    """

    def __init__(self, live: LiveIndex, directory: str, *,
                 seq: int = 0, consolidate_frac: float = 0.3,
                 keep_checkpoints: int = 3,
                 checkpoint_every_bytes: Optional[int] = None,
                 compress: bool = False,
                 fault_hook: Optional[Callable[[str], None]] = None,
                 metrics=None):
        self.live = live
        self.directory = directory
        self.seq = seq
        self.consolidate_frac = consolidate_frac
        self.keep_checkpoints = keep_checkpoints
        self.checkpoint_every_bytes = checkpoint_every_bytes
        self.compress = compress
        self.fault_hook = fault_hook
        # obs.MetricsRegistry (or None): WAL append/fsync + checkpoint
        # save/restore timings, wal_records_total{op} — purely additive,
        # recovery semantics are identical with metrics on or off
        self.metrics = metrics
        self.wal_dir = os.path.join(directory, "wal")
        self.ckpt_dir = os.path.join(directory, "ckpt")
        self._wal_bytes = 0     # on-disk record bytes since last checkpoint

    # -- construction --------------------------------------------------------
    @classmethod
    def create(cls, live: LiveIndex, directory: str,
               **kw) -> "JournaledLiveIndex":
        """Initialize a journal directory: meta + a seq-0 base checkpoint."""
        os.makedirs(directory, exist_ok=True)
        self = cls(live, directory, **kw)
        meta = {
            "kind": live.graph.kind,
            "delta": live.graph.delta,
            "params": dataclasses.asdict(live.params),
            "consolidate_frac": self.consolidate_frac,
            "checkpoint_every_bytes": self.checkpoint_every_bytes,
            "compress": self.compress,
        }
        _atomic_write(os.path.join(directory, "meta.json"),
                      json.dumps(meta).encode())
        self.checkpoint()
        return self

    # -- state snapshot ------------------------------------------------------
    def _tree(self) -> dict[str, np.ndarray]:
        g = self.live.graph
        return {
            "vectors": np.asarray(g.vectors),
            "neighbors": np.asarray(g.neighbors),
            "medoid": np.asarray(g.medoid),
            "tombstones": np.asarray(self.live.tombstones),
        }

    def checkpoint(self) -> str:
        """Commit a full snapshot at the current sequence, then drop WAL
        records no retained checkpoint still needs (older snapshots kept by
        ``keep_checkpoints`` must stay replayable — if the newest snapshot
        is later found corrupt, recovery walks back and rolls forward)."""
        t0 = time.perf_counter()
        path = save_checkpoint(self.ckpt_dir, self.seq, self._tree(),
                               keep=self.keep_checkpoints)
        if self.metrics is not None:
            self.metrics.histogram("checkpoint_save_seconds").observe(
                time.perf_counter() - t0)
        steps = list_steps(self.ckpt_dir)
        if steps:
            _truncate_wal(self.wal_dir, min(steps))
        self._wal_bytes = 0
        return path

    # -- mutations (journal first, splice second) ----------------------------
    def _fault(self, point: str) -> None:
        if self.fault_hook is not None:
            self.fault_hook(point)

    def _mutate(self, op: str, payload: dict[str, np.ndarray]) -> None:
        self._fault("before_journal")
        wal_append(self.wal_dir, self.seq + 1, op, payload,
                   fault_hook=self.fault_hook, metrics=self.metrics,
                   compress=self.compress)
        self._fault("after_journal")
        self.live = _apply_op(self.live, op, payload,
                              fault_hook=self.fault_hook)
        self.seq += 1
        self._wal_bytes += _record_bytes(self.wal_dir, self.seq)
        if self.metrics is not None:
            self.metrics.gauge("wal_bytes_since_checkpoint").set(
                self._wal_bytes)
        if (self.checkpoint_every_bytes is not None
                and self._wal_bytes >= self.checkpoint_every_bytes):
            if self.metrics is not None:
                self.metrics.counter("wal_auto_checkpoint_total").inc()
                self.metrics.gauge("wal_bytes_since_checkpoint").set(0)
            self.checkpoint()

    def insert(self, vectors) -> None:
        self._mutate("insert",
                     {"vectors": np.asarray(vectors, np.float32)})

    def delete(self, ids) -> None:
        self._mutate("delete", {"ids": np.asarray(ids, np.int64)})
        if self.live.frac_deleted > self.consolidate_frac:
            self.consolidate()

    def consolidate(self) -> None:
        self._mutate("consolidate", {})

    def search(self, queries, k: int, **kw) -> SearchResult:
        return search_live(self.live, queries, k, **kw)

    @property
    def n_live(self) -> int:
        return self.live.n_live


def recover(directory: str, metrics=None) -> tuple[JournaledLiveIndex, dict]:
    """Rebuild a ``JournaledLiveIndex`` from disk after a crash.

    Restores the newest intact checkpoint (corrupt steps walk back inside
    ``restore_latest``), then replays committed WAL records in sequence; the
    replay stops at the first missing or torn record (= the op the crash
    interrupted before its commit point — by WAL semantics it never
    happened).  Returns ``(journal, info)`` where ``info`` reports the
    checkpoint step used, the records replayed, any torn record seen, and
    the restore wall time (``elapsed_s`` — also observed into
    ``checkpoint_restore_seconds`` when ``metrics`` is given; the returned
    journal keeps the registry for its own WAL/checkpoint timings).
    """
    t_start = time.perf_counter()
    with open(os.path.join(directory, "meta.json")) as f:
        meta = json.load(f)
    params = BuildParams(**meta["params"])
    template = {
        "vectors": np.zeros((0, 0), np.float32),
        "neighbors": np.zeros((0, 0), np.int32),
        "medoid": np.zeros((), np.int32),
        "tombstones": np.zeros((0,), np.bool_),
    }
    ckpt_dir = os.path.join(directory, "ckpt")
    wal_dir = os.path.join(directory, "wal")
    step, tree = restore_latest(ckpt_dir, template)
    if step is None:
        raise FileNotFoundError(
            f"no intact checkpoint under {ckpt_dir}; cannot recover")
    graph = GraphIndex(vectors=jnp.asarray(tree["vectors"]),
                       neighbors=jnp.asarray(tree["neighbors"]),
                       medoid=jnp.asarray(tree["medoid"], jnp.int32),
                       kind=meta["kind"], delta=meta["delta"])
    live = LiveIndex(graph=graph,
                     tombstones=np.asarray(tree["tombstones"], bool),
                     params=params)
    info = {"checkpoint_step": step, "replayed": 0, "torn_seq": None}
    seq = step
    while True:
        try:
            op, payload = wal_read(wal_dir, seq + 1)
        except WalCorruptError as e:
            # torn record: crash mid-append → op never committed
            log.warning("WAL replay stops at %s", e)
            info["torn_seq"] = seq + 1
            break
        except FileNotFoundError:
            break
        live = _apply_op(live, op, payload)
        seq += 1
        info["replayed"] += 1
    info["elapsed_s"] = time.perf_counter() - t_start
    if metrics is not None:
        metrics.histogram("checkpoint_restore_seconds").observe(
            info["elapsed_s"])
    journal = JournaledLiveIndex(
        live, directory, seq=seq,
        consolidate_frac=meta.get("consolidate_frac", 0.3),
        checkpoint_every_bytes=meta.get("checkpoint_every_bytes"),
        compress=meta.get("compress", False), metrics=metrics)
    # resume the byte accumulator: committed records newer than the restored
    # checkpoint are exactly what the next auto-checkpoint threshold is over
    journal._wal_bytes = sum(_record_bytes(wal_dir, s)
                             for s in range(step + 1, seq + 1))
    return journal, info
