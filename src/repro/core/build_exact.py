"""Algorithm 2 — exact δ-EMG construction (O(n² log n)).

For every node u, all other nodes are sorted by distance and greedily
admitted unless occluded (Def. 9) by an already-admitted neighbor.  This is
the construction whose closure property Theorem 3 proves; it is intractable
past ~10⁵ points (the paper says as much) and exists here as (a) the ground
truth for property tests of the monotonicity guarantee and (b) the reference
the approximate builder (Algorithm 4) is validated against.

The per-node selection is sequential in the kept set but vectorized across
candidates, and nodes are processed in vmapped blocks — the O(n²) distance
work lands on the MXU as blocked matmuls.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .distances import medoid as find_medoid
from .distances import pairwise_sqdist
from .geometry import select_neighbors
from .types import GraphIndex


@partial(jax.jit, static_argnames=("rule", "max_keep"))
def _build_block(vectors: jax.Array, u_ids: jax.Array, delta: float,
                 rule: str, max_keep: int):
    u_vecs = jnp.take(vectors, u_ids, axis=0)
    d2 = pairwise_sqdist(u_vecs, vectors)                      # [B, n]
    order = jnp.argsort(d2, axis=1).astype(jnp.int32)          # ascending

    def one(u_vec, d2_row, order_row):
        cand_d2 = jnp.take(d2_row, order_row)
        cand_vecs = jnp.take(vectors, order_row, axis=0)
        deltas = jnp.full(order_row.shape, jnp.float32(delta))
        return select_neighbors(
            u_vec, cand_vecs, cand_d2, order_row, deltas,
            rule=rule, max_keep=max_keep,
        )

    return jax.vmap(one)(u_vecs, d2, order)


def build_exact(
    vectors,
    delta: float = 0.05,
    rule: str = "delta_emg",
    max_degree: Optional[int] = None,
    block: int = 16,
    kind: Optional[str] = None,
) -> GraphIndex:
    """Exact Algorithm-2 build.  ``rule`` selects the occlusion family, so the
    same driver also produces exact MRNG (δ→0), τ-MG and Vamana graphs for
    the baseline suite.

    ``max_degree`` caps storage; Lemma 2 gives expected degree O(log n), so
    the default ``min(n-1, 8·⌈log2 n⌉ + 32)`` overflows only on adversarial
    inputs — overflow is detected and reported (the guarantee needs every
    non-occluded edge kept).
    """
    vectors = jnp.asarray(vectors, jnp.float32)
    n = vectors.shape[0]
    if max_degree is None:
        max_degree = int(min(n - 1, 8 * np.ceil(np.log2(max(n, 2))) + 32))

    all_ids = np.full((n, max_degree), -1, np.int32)
    counts = np.zeros((n,), np.int32)
    for s in range(0, n, block):
        ids_blk = jnp.arange(s, min(s + block, n), dtype=jnp.int32)
        kept, cnt = _build_block(vectors, ids_blk, float(delta), rule, max_degree)
        all_ids[s : s + ids_blk.shape[0]] = np.asarray(kept)
        counts[s : s + ids_blk.shape[0]] = np.asarray(cnt)

    n_overflow = int((counts >= max_degree).sum())
    if n_overflow:
        import warnings

        warnings.warn(
            f"build_exact: {n_overflow}/{n} nodes hit the degree cap "
            f"{max_degree}; the δ-EMG closure may be violated for them."
        )

    med = find_medoid(vectors)
    return GraphIndex(
        vectors=vectors,
        neighbors=jnp.asarray(all_ids),
        medoid=jnp.int32(med),
        kind=kind or rule,
        delta=float(delta),
    )
