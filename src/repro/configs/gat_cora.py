"""gat-cora — 2-layer GAT (8 hidden × 8 heads).  [arXiv:1710.10903; paper]

The model dims follow the GAT paper; input features / classes vary per
shape cell (cora / reddit-minibatch / ogb_products / molecule), so
``model_cfg`` here is a dict of per-shape GATConfigs.
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, ShapeSpec, register
from repro.models.gnn import GATConfig


def _cfg(d_in, n_classes, readout=None):
    return GATConfig(name="gat-cora", n_layers=2, d_hidden=8, n_heads=8,
                     d_in=d_in, n_classes=n_classes, readout=readout,
                     dtype=jnp.float32)


SHAPE_CFGS = {
    "full_graph_sm": _cfg(1433, 7),
    "minibatch_lg": _cfg(602, 41),          # reddit-scale sampled training
    "ogb_products": _cfg(100, 47),
    "molecule": _cfg(32, 2, readout="mean"),
}

ARCH = register(ArchSpec(
    id="gat-cora",
    family="gnn",
    model_cfg=SHAPE_CFGS,
    shapes={
        "full_graph_sm": ShapeSpec(
            "full_graph_sm", "full_graph",
            {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
             "n_classes": 7}),
        "minibatch_lg": ShapeSpec(
            "minibatch_lg", "minibatch",
            # padded two-hop fanout(15,10) subgraph of reddit
            {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
             "fanout": (15, 10), "d_feat": 602, "n_classes": 41,
             "pad_nodes": 180224, "pad_edges": 180224}),
        "ogb_products": ShapeSpec(
            "ogb_products", "full_graph",
            {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100,
             "n_classes": 47}),
        "molecule": ShapeSpec(
            "molecule", "molecule",
            {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 32,
             "n_classes": 2}),
    },
    source="arXiv:1710.10903; paper",
    smoke_cfg=_cfg(16, 4),
))
