"""moonshot-v1-16b-a3b — Moonlight/Kimi-style fine-grained MoE.
[hf:moonshotai/Moonlight-16B-A3B; hf]  64 experts top-6, 2 shared experts,
first layer dense (DeepSeek-V3 recipe).  Spec dims are authoritative (they
give ~28B total / ~5.6B active with 48 layers; the HF release uses 27
layers — noted, we follow the assignment line)."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, ShapeSpec, lm_shapes, register
from repro.models.transformer import LMConfig

ARCH = register(ArchSpec(
    id="moonshot-v1-16b-a3b",
    family="lm",
    model_cfg=LMConfig(
        name="moonshot-v1-16b-a3b",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=1408, vocab=163840,
        n_experts=64, top_k=6, n_shared_experts=2,
        moe_period=1, first_dense=1,
        dtype=jnp.bfloat16,
    ),
    shapes=lm_shapes(sub_quadratic=False, accum_train=8),
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
    smoke_cfg=LMConfig(
        name="moonshot-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=96, vocab=512, n_experts=8, top_k=2,
        n_shared_experts=1, moe_period=1, first_dense=1, dtype=jnp.float32),
))
