"""llama4-maverick-400b-a17b — MoE with alternating dense/MoE layers
(moe_period=2 reproduces the ~400B total / 17B active budget), 1 shared
expert per MoE layer, iRoPE-style hybrid attention (3 of 4 layers
sliding-window 8192, every 4th global).  [hf:meta-llama/Llama-4-*;
unverified]  The modality frontend ("early fusion") is a stub per the
assignment — input_specs provide token ids for the backbone.

The hybrid attention makes this the one assigned LM that legitimately runs
``long_500k`` (see DESIGN.md §Shape-cell notes)."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, ShapeSpec, lm_shapes, register
from repro.models.transformer import LMConfig

ARCH = register(ArchSpec(
    id="llama4-maverick-400b-a17b",
    family="lm",
    model_cfg=LMConfig(
        name="llama4-maverick-400b-a17b",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab=202048,
        n_experts=128, top_k=1, n_shared_experts=1,
        moe_period=2, first_dense=0,
        window=8192, window_period=4,
        dtype=jnp.bfloat16,
    ),
    shapes=lm_shapes(sub_quadratic=True, accum_train=4),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    smoke_cfg=LMConfig(
        name="llama4-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=96, vocab=512, n_experts=8, top_k=1,
        n_shared_experts=1, moe_period=2, first_dense=0, window=16,
        window_period=4, dtype=jnp.float32),
))
