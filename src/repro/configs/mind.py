"""mind — multi-interest network w/ dynamic (capsule) routing.
[arXiv:1904.08030; unverified]  embed 64, 4 interests, 3 routing iters.
Flagship δ-EMQG integration: retrieval_cand serves per-interest ANN queries
against the item-embedding corpus."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, recsys_shapes, register
from repro.models.recsys import MINDConfig

ARCH = register(ArchSpec(
    id="mind",
    family="recsys",
    model_cfg=MINDConfig(
        name="mind", n_items=1 << 23, embed_dim=64, n_interests=4,
        routing_iters=3, seq_len=50, n_neg=16, dtype=jnp.float32),
    shapes=recsys_shapes(),
    source="arXiv:1904.08030; unverified",
    smoke_cfg=MINDConfig(name="mind-smoke", n_items=2048, embed_dim=16,
                         n_interests=4, routing_iters=3, seq_len=12, n_neg=4),
))
