"""fm — factorization machine, O(nk) sum-square pairwise term.
[ICDM'10 (Rendle); paper]  39 sparse fields, embed 10."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, recsys_shapes, register
from repro.models.recsys import FMConfig

ARCH = register(ArchSpec(
    id="fm",
    family="recsys",
    model_cfg=FMConfig(name="fm", n_sparse=39, rows=1 << 21, embed_dim=10,
                       dtype=jnp.float32),
    shapes=recsys_shapes(),
    source="ICDM'10 (Rendle); paper",
    smoke_cfg=FMConfig(name="fm-smoke", n_sparse=39, rows=512, embed_dim=10),
))
