"""sift1m — the paper's own flagship configuration: a sharded δ-EMQG index
over a SIFT-like corpus (n=1M, d=128) served with the error-bounded probing
search.  Build params follow Sec. 7 (L=1000, M=64, I=3); search uses
k ∈ {1, 10, 100} with α sweeps.

Dry-run shapes lower the *distributed serving step* (local probing search +
global top-k merge) on the production mesh — the index rows shard over
('data','model'), queries shard over 'pod' when present.
"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, ShapeSpec, register
from repro.core import BuildParams, SearchParams

ARCH = register(ArchSpec(
    id="sift1m",
    family="ann",
    model_cfg={
        "n": 1_000_000,
        "dim": 128,
        "build": BuildParams(max_degree=64, beam_width=1000, t=64, iters=3,
                             align_degree=True),
        "search": SearchParams(k=10, l0=10, l_max=512, alpha=1.2,
                               adaptive=True, max_hops=4096),
    },
    shapes={
        "serve_batch": ShapeSpec("serve_batch", "ann_serve",
                                 {"batch": 4096, "k": 10}),
        "serve_online": ShapeSpec("serve_online", "ann_serve",
                                  {"batch": 256, "k": 10}),
    },
    source="ANN-Benchmarks SIFT1M (paper Sec. 7)",
    smoke_cfg={
        "n": 2000,
        "dim": 32,
        "build": BuildParams(max_degree=16, beam_width=32, t=8, iters=2),
        "search": SearchParams(k=10, l0=10, l_max=64, alpha=1.3,
                               adaptive=True, max_hops=512),
    },
))
