"""dien — deep interest evolution (GRU + AUGRU).  [arXiv:1809.03672;
unverified]  embed 18, seq 100, gru 108, MLP 200-80."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, recsys_shapes, register
from repro.models.recsys import DIENConfig

ARCH = register(ArchSpec(
    id="dien",
    family="recsys",
    model_cfg=DIENConfig(
        name="dien", n_items=1 << 22, n_cats=1 << 12, embed_dim=18,
        seq_len=100, gru_dim=108, mlp_dims=(200, 80), dtype=jnp.float32),
    shapes=recsys_shapes(),
    source="arXiv:1809.03672; unverified",
    smoke_cfg=DIENConfig(name="dien-smoke", n_items=2048, n_cats=64,
                         embed_dim=8, seq_len=12, gru_dim=24,
                         mlp_dims=(32, 16)),
))
