"""Arch/shape registry: every assigned architecture is a selectable config
(``--arch <id>``), each carrying its own input-shape set (the 40 dry-run
cells) plus smoke-test reduced configs.

An ArchSpec is declarative — the launch layer (``repro.launch.steps``) turns
(arch × shape) into a concrete step function + ShapeDtypeStruct inputs +
shardings.  ``skip`` marks cells that are intentionally not runnable for the
family (with the reason recorded; see DESIGN.md §Shape-cell notes).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Optional


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                   # train | prefill | decode | serve | retrieval
                                # | full_graph | minibatch | molecule
    dims: dict                  # family-specific dimensions
    skip: Optional[str] = None  # reason string → cell intentionally skipped
    accum_steps: int = 1        # microbatch accumulation for train kinds


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    id: str
    family: str                 # lm | gnn | recsys | ann
    model_cfg: Any              # family config dataclass (or factory)
    shapes: dict[str, ShapeSpec]
    source: str = ""            # provenance note from the assignment
    notes: str = ""
    smoke_cfg: Any = None       # reduced config for CPU smoke tests

    def shape(self, name: str) -> ShapeSpec:
        return self.shapes[name]


_ARCH_MODULES = [
    "moonshot_v1_16b_a3b",
    "llama4_maverick_400b_a17b",
    "internlm2_20b",
    "phi3_mini_3_8b",
    "smollm_135m",
    "gat_cora",
    "mind",
    "dien",
    "fm",
    "dcn_v2",
    "sift1m",
]

_REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    if not _REGISTRY:
        load_all()
    norm = arch_id.replace("-", "_").replace(".", "_")
    for key, spec in _REGISTRY.items():
        if key == arch_id or key.replace("-", "_").replace(".", "_") == norm:
            return spec
    raise KeyError(f"unknown arch '{arch_id}'; have {sorted(_REGISTRY)}")


def all_archs() -> list[ArchSpec]:
    if not _REGISTRY:
        load_all()
    return [v for v in _REGISTRY.values()]


def load_all() -> None:
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


# ---- shared shape-set builders --------------------------------------------

def lm_shapes(*, sub_quadratic: bool, accum_train: int = 8) -> dict[str, ShapeSpec]:
    skip = (None if sub_quadratic else
            "pure full-attention arch — long_500k needs sub-quadratic "
            "attention (DESIGN.md §Shape-cell notes)")
    return {
        "train_4k": ShapeSpec("train_4k", "train",
                              {"seq": 4096, "batch": 256},
                              accum_steps=accum_train),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                                 {"seq": 32768, "batch": 32}),
        "decode_32k": ShapeSpec("decode_32k", "decode",
                                {"seq": 32768, "batch": 128}),
        "long_500k": ShapeSpec("long_500k", "decode",
                               {"seq": 524288, "batch": 1}, skip=skip),
    }


def recsys_shapes() -> dict[str, ShapeSpec]:
    return {
        "train_batch": ShapeSpec("train_batch", "train", {"batch": 65536}),
        "serve_p99": ShapeSpec("serve_p99", "serve", {"batch": 512}),
        "serve_bulk": ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
        "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                    {"batch": 1, "n_candidates": 1_000_000}),
    }
