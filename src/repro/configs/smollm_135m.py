"""smollm-135m — llama-arch small dense.  [hf:HuggingFaceTB/SmolLM-135M; hf]"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import LMConfig

ARCH = register(ArchSpec(
    id="smollm-135m",
    family="lm",
    model_cfg=LMConfig(
        name="smollm-135m",
        n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, head_dim=64,
        d_ff=1536, vocab=49152, dtype=jnp.bfloat16,
    ),
    shapes=lm_shapes(sub_quadratic=False, accum_train=4),
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
    smoke_cfg=LMConfig(
        name="smollm-smoke", n_layers=3, d_model=48, n_heads=3, n_kv_heads=3,
        head_dim=16, d_ff=128, vocab=512, dtype=jnp.float32),
))
