"""internlm2-20b — dense GQA decoder.  [arXiv:2403.17297; hf]"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import LMConfig

ARCH = register(ArchSpec(
    id="internlm2-20b",
    family="lm",
    model_cfg=LMConfig(
        name="internlm2-20b",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab=92544, dtype=jnp.bfloat16,
    ),
    shapes=lm_shapes(sub_quadratic=False, accum_train=16),
    source="arXiv:2403.17297; hf",
    smoke_cfg=LMConfig(
        name="internlm2-smoke", n_layers=3, d_model=96, n_heads=6,
        n_kv_heads=2, head_dim=16, d_ff=192, vocab=512, dtype=jnp.float32),
))
