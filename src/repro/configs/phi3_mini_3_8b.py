"""phi3-mini-3.8b — dense, RoPE/SwiGLU/GQA (kv=32 → MHA-like).
[arXiv:2404.14219; unverified]"""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import LMConfig

ARCH = register(ArchSpec(
    id="phi3-mini-3.8b",
    family="lm",
    model_cfg=LMConfig(
        name="phi3-mini-3.8b",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
        d_ff=8192, vocab=32064, dtype=jnp.bfloat16,
    ),
    shapes=lm_shapes(sub_quadratic=False, accum_train=8),
    source="arXiv:2404.14219; unverified",
    smoke_cfg=LMConfig(
        name="phi3-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=512, dtype=jnp.float32),
))
