"""Per-architecture configs (one module per assigned arch + the paper's own
SIFT1M serving config).  ``get_arch`` / ``all_archs`` are the public API."""

from .base import ArchSpec, ShapeSpec, all_archs, get_arch, load_all  # noqa: F401
