"""dcn-v2 — cross network v2.  [arXiv:2008.13535; paper]
13 dense + 26 sparse × 16, 3 cross layers, MLP 1024-1024-512."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, recsys_shapes, register
from repro.models.recsys import DCNConfig

ARCH = register(ArchSpec(
    id="dcn-v2",
    family="recsys",
    model_cfg=DCNConfig(
        name="dcn-v2", n_dense=13, n_sparse=26, rows=1 << 21, embed_dim=16,
        n_cross=3, mlp_dims=(1024, 1024, 512), dtype=jnp.float32),
    shapes=recsys_shapes(),
    source="arXiv:2008.13535; paper",
    smoke_cfg=DCNConfig(name="dcn-smoke", n_dense=13, n_sparse=26, rows=512,
                        embed_dim=8, n_cross=2, mlp_dims=(64, 32)),
))
