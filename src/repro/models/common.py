"""Shared neural building blocks (pure JAX — no flax/haiku dependency).

Parameters are plain nested dicts of jnp arrays; initializers take an
explicit PRNG key.  Everything here is shape-polymorphic and dtype-explicit
so the same code path serves tiny smoke configs and the 400B dry-run
configs.

Key pieces:
  * rms_norm / swiglu / dense init helpers
  * rope — rotary position embeddings (half-rotation convention)
  * flash_attention — memory-O(S·block) online-softmax attention in pure
    jnp (lax.scan over KV blocks).  This is what keeps the 4k-train and
    32k-prefill dry-runs inside HBM without a custom kernel: XLA never
    materializes the S×S score matrix.  Supports causal and sliding-window
    masking and GQA head groups.
  * decode_attention — single-token attention against a KV cache.
  * gru_cell / gru_scan — for DIEN.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

Params = dict
DEFAULT_DTYPE = jnp.bfloat16


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * gamma.astype(jnp.float32)).astype(dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x [..., S, H, hd], positions [..., S] (int) → same shape."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash-style attention (pure jnp, blockwise online softmax)
# ---------------------------------------------------------------------------

def flash_attention(
    q: jax.Array,             # [B, S, H, hd]
    k: jax.Array,             # [B, S, KV, hd]
    v: jax.Array,             # [B, S, KV, hd]
    causal: bool = True,
    window: Optional[int] = None,   # sliding-window size (None → full)
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    """Online-softmax attention; GQA via KV-head broadcast; O(S·block) memory."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    groups = H // KV
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq, nk = -(-S // block_q), -(-S // block_k)
    pad_q, pad_k = nq * block_q - S, nk * block_k - S
    # keep K/V in their storage dtype (a full-sequence f32 upcast would
    # double the 32k-prefill working set); accumulate in f32 via
    # preferred_element_type inside the per-block einsums.
    # GQA broadcast happens HERE, outside the block loops: a repeat inside
    # the kv scan makes its backward emit a cross-'model' grad reduce per
    # block (~8 MB × n_q·n_k blocks per layer — dominated the smollm
    # collective term); hoisted, it is one reduce per layer.
    qf = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kf = jnp.pad(jnp.repeat(k, groups, axis=2), ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vf = jnp.pad(jnp.repeat(v, groups, axis=2), ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    # [B, nq, bq, H, hd] / [B, nk, bk, H, hd]
    qf = qf.reshape(B, nq, block_q, H, hd)
    kf = kf.reshape(B, nk, block_k, H, hd)
    vf = vf.reshape(B, nk, block_k, H, hd)

    q_pos = jnp.arange(nq * block_q).reshape(nq, block_q)
    k_pos = jnp.arange(nk * block_k).reshape(nk, block_k)

    def per_qblock(qi, qblk):
        # qblk [B, bq, H, hd]
        qpos = q_pos[qi]                                     # [bq]

        def kv_step(carry, inp):
            acc, m, denom = carry
            kb, vb, kpos = inp                                # [B,bk,H,hd],[bk]
            # scores [B, bq, H, bk]
            s = jnp.einsum("bqhd,bkhd->bqhk", qblk, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            mask &= (kpos < S)[None, :]
            s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows (m_new = -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, :, None, :], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqhk,bkhd->bqhd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            denom = denom * corr + jnp.sum(p, axis=-1)
            return (acc, m_safe, denom), None

        acc0 = jnp.zeros((B, block_q, H, hd), jnp.float32)
        m0 = jnp.full((B, block_q, H), -jnp.inf, jnp.float32)
        d0 = jnp.zeros((B, block_q, H), jnp.float32)
        (acc, _, denom), _ = jax.lax.scan(
            kv_step, (acc0, m0, d0),
            (kf.swapaxes(0, 1), vf.swapaxes(0, 1), k_pos))
        return acc / jnp.maximum(denom[..., None], 1e-30)

    out = jax.lax.map(lambda args: per_qblock(*args),
                      (jnp.arange(nq), qf.swapaxes(0, 1)))    # [nq, B, bq, H, hd]
    out = out.swapaxes(0, 1).reshape(B, nq * block_q, H, hd)[:, :S]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,        # [B, H, hd]      one new token per sequence
    k_cache: jax.Array,  # [B, S, KV, hd]
    v_cache: jax.Array,  # [B, S, KV, hd]
    pos: jax.Array,      # [B] int32 — number of valid cache entries
    window: Optional[int] = None,
) -> jax.Array:
    B, S, KV, hd = k_cache.shape
    H = q.shape[1]
    groups = H // KV
    scale = 1.0 / math.sqrt(hd)
    # grouped GQA einsum: no repeat — materializing a [B,S,H,hd] broadcast
    # of the cache costs groups× memory and forces the partitioner to
    # reshard the multi-GB cache (hd→heads) every layer.  The grouped form
    # contracts the hd-sharded cache locally; only the [B,KV,G,S] scores
    # and [B,H,hd] outputs cross the 'model' axis.
    q3 = q.reshape(B, KV, groups, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", q3.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(S)[None, :]
    mask = idx < pos[:, None]
    if window is not None:
        mask &= idx >= (pos[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# GRU (for DIEN) — scan over time
# ---------------------------------------------------------------------------

def gru_init(key, d_in: int, d_h: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_x": dense_init(k1, d_in, 3 * d_h, dtype),
        "w_h": dense_init(k2, d_h, 3 * d_h, dtype),
        "b": jnp.zeros((3 * d_h,), dtype),
    }


def gru_cell(p: Params, h: jax.Array, x: jax.Array,
             att: Optional[jax.Array] = None) -> jax.Array:
    """One GRU step; ``att`` (per-example scalar) turns it into AUGRU
    (attention-update gate, DIEN eq. 5)."""
    zx = x @ p["w_x"] + h @ p["w_h"] + p["b"]
    z, r, n = jnp.split(zx, 3, axis=-1)
    z = jax.nn.sigmoid(z)
    r = jax.nn.sigmoid(r)
    n = jnp.tanh(x @ p["w_x"][:, -n.shape[-1]:] + (r * h) @ p["w_h"][:, -n.shape[-1]:]
                 + p["b"][-n.shape[-1]:])
    if att is not None:
        z = z * att[..., None]
    return (1.0 - z) * h + z * n


def gru_scan(p: Params, xs: jax.Array, h0: Optional[jax.Array] = None,
             atts: Optional[jax.Array] = None) -> tuple[jax.Array, jax.Array]:
    """xs [B, T, d_in] → (all states [B, T, d_h], final state [B, d_h])."""
    B, T, _ = xs.shape
    d_h = p["w_h"].shape[0]
    if h0 is None:
        h0 = jnp.zeros((B, d_h), xs.dtype)

    def step(h, inp):
        if atts is None:
            x = inp
            h = gru_cell(p, h, x)
        else:
            x, a = inp
            h = gru_cell(p, h, x, a)
        return h, h

    inputs = xs.swapaxes(0, 1) if atts is None else (xs.swapaxes(0, 1), atts.swapaxes(0, 1))
    hT, hs = jax.lax.scan(step, h0, inputs)
    return hs.swapaxes(0, 1), hT


def mlp_init(key, dims: list[int], dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"w{i}": dense_init(keys[i], dims[i], dims[i + 1], dtype)
        for i in range(len(dims) - 1)
    } | {
        f"b{i}": jnp.zeros((dims[i + 1],), dtype)
        for i in range(len(dims) - 1)
    }


def mlp_apply(p: Params, x: jax.Array, n_layers: int,
              final_act: bool = False) -> jax.Array:
    for i in range(n_layers):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n_layers - 1 or final_act:
            x = jax.nn.relu(x)
    return x
