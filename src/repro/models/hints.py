"""Activation-sharding hints.

Model code calls ``constrain(x, "<name>")`` at layout-critical points;
the launch layer activates a policy (mesh + name→PartitionSpec) around
tracing.  With no active policy (unit tests, single device) the calls are
no-ops, so model code stays mesh-agnostic.

Why this exists: XLA SPMD propagates shardings from inputs, but for deep
scanned stacks + gathers (embedding lookups, MoE dispatch) propagation can
settle on batch-replicated activations, which turns every TP partial-sum
into a full-tensor all-reduce.  One constraint after the embedding and one
per tile boundary pins the intended layout (observed: smollm prefill
25.7 GB → MBs of all-reduce traffic per device).
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_policy: contextvars.ContextVar = contextvars.ContextVar(
    "sharding_policy", default=None)


@contextlib.contextmanager
def use_policy(mesh, specs: dict):
    tok = _policy.set((mesh, specs))
    try:
        yield
    finally:
        _policy.reset(tok)


def constrain(x: jax.Array, name: str) -> jax.Array:
    pol = _policy.get()
    if pol is None:
        return x
    mesh, specs = pol
    spec = specs.get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
