"""Recommendation models: FM, DCN-v2, DIEN, MIND + the embedding substrate.

JAX has no native EmbeddingBag — per the assignment it is built here from
``jnp.take`` + ``jax.ops.segment_sum``.  Sparse categorical fields use the
hashing trick into per-field row ranges of one stacked table
``[n_fields, rows, dim]`` so the whole embedding state is a single
row-shardable array (rows over the 'model' axis → embedding parallelism;
XLA SPMD turns the lookups into all-gather-free dynamic gathers + a
reduce-scatter on the backward scatter-add).

The paper's technique lands in ``retrieval``: the `retrieval_cand` shape
scores one user query against 10⁶ candidate items — brute-force tiled
matmul (`retrieval_scores_exact`, the roofline baseline) or a δ-EMQG graph
index (`repro.core`), which benchmarks compare head-to-head.

Models (all return (loss, metrics) from a batch dict):
  FM      — 2-way factorization machine, O(nk) sum-square trick (Rendle'10)
  DCN-v2  — cross network v2, 3 full-rank cross layers + deep tower
  DIEN    — GRU interest extractor + AUGRU interest evolution (target attn)
  MIND    — multi-interest B2I capsule routing (3 iters, 4 capsules)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .common import dense_init, gru_init, gru_scan, mlp_apply, mlp_init


# ---------------------------------------------------------------------------
# Embedding substrate
# ---------------------------------------------------------------------------

def embedding_table_init(key, n_fields: int, rows: int, dim: int,
                         dtype=jnp.float32) -> jax.Array:
    """Stacked per-field table, stored FLAT [n_fields·rows, dim] so the row
    axis is shardable over 'model' without reshaping a sharded dim."""
    return (jax.random.normal(key, (n_fields * rows, dim), jnp.float32)
            * 0.01).astype(dtype)


def field_lookup_flat(table: jax.Array, ids: jax.Array, rows: int) -> jax.Array:
    """table [F·rows, d], ids int32[B, F] (one id per field) → [B, F, d].
    Per-field row ranges via offsets; the whole lookup is a single row
    gather (one DMA stream, one scatter-add on the backward pass)."""
    F = ids.shape[1]
    offs = jnp.arange(F, dtype=ids.dtype) * rows
    return jnp.take(table, jnp.clip(ids, 0, rows - 1) + offs[None, :], axis=0)


def embedding_bag(table: jax.Array, ids: jax.Array, mask: jax.Array,
                  mode: str = "mean") -> jax.Array:
    """EmbeddingBag: table [R, d], ids int32[B, L], mask bool[B, L] → [B, d].

    take + masked segment-style reduction (the segment ids here are the
    batch rows, so the reduction is a masked sum along L).
    """
    R = table.shape[0]
    rows = jnp.take(table, jnp.clip(ids, 0, R - 1), axis=0)      # [B, L, d]
    rows = jnp.where(mask[:, :, None], rows, 0.0)
    s = jnp.sum(rows, axis=1)
    if mode == "sum":
        return s
    return s / jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)


# ---------------------------------------------------------------------------
# FM
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FMConfig:
    name: str = "fm"
    n_sparse: int = 39
    rows: int = 1 << 21
    embed_dim: int = 10
    dtype: Any = jnp.float32


def fm_init(cfg: FMConfig, key) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "emb": embedding_table_init(k1, cfg.n_sparse, cfg.rows, cfg.embed_dim,
                                    cfg.dtype),
        "lin": embedding_table_init(k2, cfg.n_sparse, cfg.rows, 1, cfg.dtype),
        "bias": jnp.zeros((), jnp.float32),
    }


def fm_forward(cfg: FMConfig, params: dict, sparse_ids: jax.Array) -> jax.Array:
    """sparse_ids int32[B, F] → logit f32[B]."""
    v = field_lookup_flat(params["emb"], sparse_ids, cfg.rows)          # [B, F, k]
    w = field_lookup_flat(params["lin"], sparse_ids, cfg.rows)[..., 0]  # [B, F]
    sum_v = jnp.sum(v, axis=1)                                # [B, k]
    sum_v2 = jnp.sum(v * v, axis=1)
    pair = 0.5 * jnp.sum(sum_v * sum_v - sum_v2, axis=-1)     # O(nk) trick
    return (params["bias"] + jnp.sum(w, axis=1) + pair).astype(jnp.float32)


def fm_loss(cfg: FMConfig, params: dict, batch: dict):
    logit = fm_forward(cfg, params, batch["sparse_ids"])
    return _bce(logit, batch["label"])


# ---------------------------------------------------------------------------
# DCN-v2
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DCNConfig:
    name: str = "dcn-v2"
    n_dense: int = 13
    n_sparse: int = 26
    rows: int = 1 << 21
    embed_dim: int = 16
    n_cross: int = 3
    mlp_dims: tuple = (1024, 1024, 512)
    dtype: Any = jnp.float32

    @property
    def d_input(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim


def dcn_init(cfg: DCNConfig, key) -> dict:
    ks = jax.random.split(key, 3 + cfg.n_cross)
    d = cfg.d_input
    p = {
        "emb": embedding_table_init(ks[0], cfg.n_sparse, cfg.rows,
                                    cfg.embed_dim, cfg.dtype),
        "mlp": mlp_init(ks[1], [d, *cfg.mlp_dims], cfg.dtype),
        "head": dense_init(ks[2], cfg.mlp_dims[-1], 1, cfg.dtype),
    }
    for i in range(cfg.n_cross):
        p[f"cross_w{i}"] = dense_init(ks[3 + i], d, d, cfg.dtype)
        p[f"cross_b{i}"] = jnp.zeros((d,), cfg.dtype)
    return p


def dcn_forward(cfg: DCNConfig, params: dict, dense: jax.Array,
                sparse_ids: jax.Array) -> jax.Array:
    """dense f32[B, 13], sparse_ids int32[B, 26] → logit f32[B]."""
    emb = field_lookup_flat(params["emb"], sparse_ids, cfg.rows)   # [B, 26, 16]
    x0 = jnp.concatenate([dense.astype(cfg.dtype),
                          emb.reshape(emb.shape[0], -1)], axis=-1)
    x = x0
    for i in range(cfg.n_cross):                              # x_{l+1} = x0∘(Wx+b)+x
        x = x0 * (x @ params[f"cross_w{i}"] + params[f"cross_b{i}"]) + x
    h = mlp_apply(params["mlp"], x, len(cfg.mlp_dims), final_act=True)
    return (h @ params["head"])[:, 0].astype(jnp.float32)


def dcn_loss(cfg: DCNConfig, params: dict, batch: dict):
    logit = dcn_forward(cfg, params, batch["dense"], batch["sparse_ids"])
    return _bce(logit, batch["label"])


# ---------------------------------------------------------------------------
# DIEN
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DIENConfig:
    name: str = "dien"
    n_items: int = 1 << 22
    n_cats: int = 1 << 12
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp_dims: tuple = (200, 80)
    dtype: Any = jnp.float32

    @property
    def d_beh(self) -> int:
        return 2 * self.embed_dim      # item ⊕ category


def dien_init(cfg: DIENConfig, key) -> dict:
    ks = jax.random.split(key, 7)
    d_beh, gd = cfg.d_beh, cfg.gru_dim
    return {
        "item_emb": embedding_table_init(ks[0], 1, cfg.n_items,
                                         cfg.embed_dim, cfg.dtype),
        "cat_emb": embedding_table_init(ks[1], 1, cfg.n_cats,
                                        cfg.embed_dim, cfg.dtype),
        "gru1": gru_init(ks[2], d_beh, gd, cfg.dtype),          # interest extractor
        "gru2": gru_init(ks[3], gd, gd, cfg.dtype),             # interest evolution
        "att_w": dense_init(ks[4], gd, d_beh, cfg.dtype),       # target attention
        "mlp": mlp_init(ks[5], [gd + 2 * d_beh, *cfg.mlp_dims], cfg.dtype),
        "head": dense_init(ks[6], cfg.mlp_dims[-1], 1, cfg.dtype),
    }


def _behavior_embed(cfg: DIENConfig, params: dict, item_ids, cat_ids):
    e_i = jnp.take(params["item_emb"], jnp.clip(item_ids, 0, cfg.n_items - 1), axis=0)
    e_c = jnp.take(params["cat_emb"], jnp.clip(cat_ids, 0, cfg.n_cats - 1), axis=0)
    return jnp.concatenate([e_i, e_c], axis=-1)


def dien_forward(cfg: DIENConfig, params: dict, batch: dict) -> jax.Array:
    """batch: hist_items/hist_cats int32[B, T], hist_mask bool[B, T],
    target_item/target_cat int32[B] → logit f32[B]."""
    beh = _behavior_embed(cfg, params, batch["hist_items"], batch["hist_cats"])
    tgt = _behavior_embed(cfg, params, batch["target_item"][:, None],
                          batch["target_cat"][:, None])[:, 0]   # [B, d_beh]
    mask = batch["hist_mask"]
    beh = jnp.where(mask[:, :, None], beh, 0.0)

    h_states, _ = gru_scan(params["gru1"], beh)                 # [B, T, gd]
    # AUGRU: attention of each interest state against the target
    scores = jnp.einsum("btg,gd,bd->bt", h_states, params["att_w"], tgt)
    scores = jnp.where(mask, scores, -jnp.inf)
    att = jax.nn.softmax(scores, axis=-1)
    att = jnp.where(mask, att, 0.0)
    _, h_final = gru_scan(params["gru2"], h_states, atts=att)   # [B, gd]

    beh_sum = jnp.sum(beh, axis=1) / jnp.maximum(
        jnp.sum(mask, axis=1, keepdims=True), 1.0)
    feat = jnp.concatenate([h_final, tgt, beh_sum], axis=-1)
    h = mlp_apply(params["mlp"], feat, len(cfg.mlp_dims), final_act=True)
    return (h @ params["head"])[:, 0].astype(jnp.float32)


def dien_loss(cfg: DIENConfig, params: dict, batch: dict):
    return _bce(dien_forward(cfg, params, batch), batch["label"])


# ---------------------------------------------------------------------------
# MIND
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    n_items: int = 1 << 22
    embed_dim: int = 64
    n_interests: int = 4
    routing_iters: int = 3
    seq_len: int = 50
    n_neg: int = 16
    pow_p: float = 2.0                 # label-aware attention sharpness
    dtype: Any = jnp.float32


def mind_init(cfg: MINDConfig, key) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.embed_dim
    return {
        "item_emb": embedding_table_init(k1, 1, cfg.n_items, d, cfg.dtype),
        "s_bilinear": dense_init(k2, d, d, cfg.dtype),           # shared B2I map
        "b_init": (jax.random.normal(k3, (cfg.n_interests,), jnp.float32)
                   * 0.1).astype(cfg.dtype),
    }


def _squash(x: jax.Array) -> jax.Array:
    n2 = jnp.sum(x * x, axis=-1, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def mind_user_interests(cfg: MINDConfig, params: dict, hist_items: jax.Array,
                        hist_mask: jax.Array) -> jax.Array:
    """B2I dynamic routing: hist [B, T] → interest capsules [B, K, d]."""
    e = jnp.take(params["item_emb"], jnp.clip(hist_items, 0, cfg.n_items - 1),
                 axis=0)                                          # [B, T, d]
    low = jnp.einsum("btd,de->bte", e, params["s_bilinear"])      # S·e_i
    low = jnp.where(hist_mask[:, :, None], low, 0.0)
    B, T, d = low.shape
    K = cfg.n_interests
    b_logits = jnp.broadcast_to(params["b_init"][None, None, :],
                                (B, T, K)).astype(jnp.float32)

    caps = jnp.zeros((B, K, d), low.dtype)
    for _ in range(cfg.routing_iters):
        c = jax.nn.softmax(b_logits, axis=-1)                    # over capsules
        c = jnp.where(hist_mask[:, :, None], c, 0.0)
        caps = _squash(jnp.einsum("btk,btd->bkd", c, low))
        b_logits = b_logits + jnp.einsum("bkd,btd->btk", caps, low)
    return caps


def mind_loss(cfg: MINDConfig, params: dict, batch: dict):
    """Sampled-softmax training with label-aware attention (paper §4.3).
    batch: hist_items [B,T], hist_mask [B,T], target_item [B],
    neg_items [B, n_neg]."""
    caps = mind_user_interests(cfg, params, batch["hist_items"],
                               batch["hist_mask"])                # [B, K, d]
    tgt = jnp.take(params["item_emb"],
                   jnp.clip(batch["target_item"], 0, cfg.n_items - 1), axis=0)
    # label-aware attention: user vector = Σ softmax((v·e)^p) v
    att = jnp.einsum("bkd,bd->bk", caps, tgt)
    att = jax.nn.softmax(jnp.power(jnp.abs(att), cfg.pow_p)
                         * jnp.sign(att), axis=-1)
    user = jnp.einsum("bk,bkd->bd", att, caps)                    # [B, d]
    neg = jnp.take(params["item_emb"],
                   jnp.clip(batch["neg_items"], 0, cfg.n_items - 1), axis=0)
    pos_logit = jnp.einsum("bd,bd->b", user, tgt)
    neg_logit = jnp.einsum("bd,bnd->bn", user, neg)
    logits = jnp.concatenate([pos_logit[:, None], neg_logit], axis=1)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    loss = -jnp.mean(logp[:, 0])
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == 0).astype(jnp.float32))
    return loss, {"acc": acc}


def mind_serve_scores(cfg: MINDConfig, params: dict, hist_items, hist_mask,
                      cand_items: jax.Array) -> jax.Array:
    """Serving: max-over-interests score against candidates [B, C] → [B, C]."""
    caps = mind_user_interests(cfg, params, hist_items, hist_mask)
    cand = jnp.take(params["item_emb"], jnp.clip(cand_items, 0, cfg.n_items - 1),
                    axis=0)                                       # [B, C, d]
    scores = jnp.einsum("bkd,bcd->bkc", caps, cand)
    return jnp.max(scores, axis=1)


# ---------------------------------------------------------------------------
# Retrieval scoring — the δ-EMG integration point
# ---------------------------------------------------------------------------

def retrieval_scores_exact(query: jax.Array, item_table: jax.Array,
                           k: int = 100) -> tuple[jax.Array, jax.Array]:
    """Brute-force candidate scoring: query [B, d] (or [B, K, d] multi-
    interest) against item_table [C, d]; returns top-k (scores, ids).
    This is the roofline-measurable dense path; the δ-EMQG path lives in
    repro.core (see benchmarks/retrieval.py for the comparison)."""
    if query.ndim == 3:
        s = jnp.einsum("bkd,cd->bkc", query, item_table)
        s = jnp.max(s, axis=1)
    else:
        s = jnp.einsum("bd,cd->bc", query, item_table)
    return jax.lax.top_k(s, k)


def fm_retrieval(cfg: FMConfig, params: dict, user_ids: jax.Array,
                 cand_ids: jax.Array, k: int = 100):
    """FM as a retrieval scorer: query = Σ user-field latent vectors; the
    candidate item lives in field 0.  score(q, i) = ⟨q, v_i⟩ + w_i.
    user_ids int32[B, F−1] (fields 1..F−1), cand_ids int32[C]."""
    F, R = cfg.n_sparse, cfg.rows
    flat = params["emb"]
    offs = jnp.arange(1, F, dtype=user_ids.dtype) * R
    uv = jnp.take(flat, jnp.clip(user_ids, 0, R - 1) + offs[None, :], axis=0)
    q = jnp.sum(uv, axis=1)                                   # [B, k]
    iv = jnp.take(flat, jnp.clip(cand_ids, 0, R - 1), axis=0)  # field-0 rows
    iw = jnp.take(params["lin"], jnp.clip(cand_ids, 0, R - 1), axis=0)[:, 0]
    scores = q @ iv.T + iw[None, :]
    return jax.lax.top_k(scores.astype(jnp.float32), k)


def dcn_retrieval(cfg: DCNConfig, params: dict, dense: jax.Array,
                  user_sparse: jax.Array, cand_ids: jax.Array, k: int = 100):
    """Full-model offline scoring of C candidates for one user context:
    user features broadcast across candidates, candidate id fills sparse
    field 0.  dense [1, 13], user_sparse [1, 25], cand_ids [C]."""
    C = cand_ids.shape[0]
    sparse = jnp.concatenate(
        [cand_ids[:, None],
         jnp.broadcast_to(user_sparse, (C, cfg.n_sparse - 1))], axis=1)
    logit = dcn_forward(cfg, params, jnp.broadcast_to(dense, (C, cfg.n_dense)),
                        sparse)
    score, idx = jax.lax.top_k(logit, k)
    return score[None], jnp.take(cand_ids, idx)[None]


def dien_retrieval(cfg: DIENConfig, params: dict, batch: dict,
                   cand_ids: jax.Array, k: int = 100):
    """DIEN candidate scoring: GRU1 interest extraction runs once per user;
    the target-conditioned attention + AUGRU + MLP head run per candidate
    (candidates as the batch axis — shardable over the whole mesh)."""
    C = cand_ids.shape[0]
    beh = _behavior_embed(cfg, params, batch["hist_items"], batch["hist_cats"])
    mask = batch["hist_mask"]                                   # [1, T]
    beh = jnp.where(mask[:, :, None], beh, 0.0)
    h_states, _ = gru_scan(params["gru1"], beh)                 # [1, T, g]

    tgt = _behavior_embed(cfg, params, cand_ids[:, None],
                          (cand_ids % cfg.n_cats)[:, None])[:, 0]  # [C, d_beh]
    h_rep = jnp.broadcast_to(h_states, (C,) + h_states.shape[1:])
    m_rep = jnp.broadcast_to(mask, (C, mask.shape[1]))
    scores = jnp.einsum("ctg,gd,cd->ct", h_rep, params["att_w"], tgt)
    scores = jnp.where(m_rep, scores, -jnp.inf)
    att = jax.nn.softmax(scores, axis=-1)
    att = jnp.where(m_rep, att, 0.0)
    _, h_final = gru_scan(params["gru2"], h_rep, atts=att)      # [C, g]
    beh_sum = jnp.sum(beh, axis=1) / jnp.maximum(
        jnp.sum(mask, axis=1, keepdims=True), 1.0)
    feat = jnp.concatenate(
        [h_final, tgt, jnp.broadcast_to(beh_sum, (C, beh_sum.shape[1]))], axis=-1)
    h = mlp_apply(params["mlp"], feat, len(cfg.mlp_dims), final_act=True)
    logit = (h @ params["head"])[:, 0].astype(jnp.float32)
    score, idx = jax.lax.top_k(logit, k)
    return score[None], jnp.take(cand_ids, idx)[None]


def mind_retrieval(cfg: MINDConfig, params: dict, hist_items, hist_mask,
                   cand_ids: jax.Array, k: int = 100):
    """MIND retrieval: max-over-interest dot scores against the candidate
    table — the cell the δ-EMQG index replaces with graph search (see
    benchmarks/retrieval.py for exact-vs-index comparison)."""
    caps = mind_user_interests(cfg, params, hist_items, hist_mask)  # [B,K,d]
    cand = jnp.take(params["item_emb"], jnp.clip(cand_ids, 0, cfg.n_items - 1),
                    axis=0)                                         # [C, d]
    scores = jnp.einsum("bkd,cd->bkc", caps, cand)
    scores = jnp.max(scores, axis=1).astype(jnp.float32)            # [B, C]
    score, idx = jax.lax.top_k(scores, k)
    return score, jnp.take(cand_ids, idx)


def _bce(logit: jax.Array, label: jax.Array):
    label = label.astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logit, 0) - logit * label
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))
    acc = jnp.mean(((logit > 0) == (label > 0.5)).astype(jnp.float32))
    return loss, {"acc": acc}
