"""Model zoo: the 10 assigned architectures.

transformer.py — 5 LM archs (dense + MoE decoder LMs)
gnn.py         — gat-cora (+ the 4 graph shapes)
recsys.py      — mind / dien / fm / dcn-v2 (+ embedding substrate)
"""
