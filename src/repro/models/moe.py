"""Mixture-of-Experts FFN layer (capacity-based, grouped sort-dispatch).

Top-k routing with a fixed per-expert capacity.  Dispatch is computed
within ``n_groups`` independent token groups (launchers set n_groups = the
data-parallel world so each DP shard dispatches only its own tokens — the
same contract real EP systems use):

  * every sort/searchsorted/scatter is *batched over the group axis*, so
    under pjit the group axis shards over ('pod','data') and no global
    argsort (which XLA SPMD can only realize by full replication —
    observed 25+ GB of involuntary all-gathers on the 16B MoE) ever
    appears;
  * capacity is per group: C = ceil(T_g·k/E · cf) — token drop behavior is
    then *identical* between a sharded run and a single-host run with the
    same group count (deterministic parity for tests).

Expert tiles [G, E, C, d] shard G over dp and E over 'model' (expert
parallelism); XLA inserts the all-to-all at the tile boundary.

Aux outputs: Switch load-balance loss, router z-loss, drop fraction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init
from .hints import constrain


def moe_init(key, d_model: int, d_ff: int, n_experts: int, dtype) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "w_gate": jax.vmap(lambda k: dense_init(k, d_model, d_ff, dtype))(
            jax.random.split(ks[1], n_experts)),
        "w_up": jax.vmap(lambda k: dense_init(k, d_model, d_ff, dtype))(
            jax.random.split(ks[2], n_experts)),
        "w_down": jax.vmap(lambda k: dense_init(k, d_ff, d_model, dtype))(
            jax.random.split(ks[3], n_experts)),
    }


def moe_apply(p: dict, x: jax.Array, top_k: int,
              capacity_factor: float = 1.25, n_groups: int = 1):
    """x [T, d] → (out [T, d], aux).  T must divide by n_groups."""
    T, d = x.shape
    E = p["router"].shape[1]
    G = max(min(n_groups, T), 1)
    while T % G:
        G -= 1
    Tg = T // G
    C = max(int(((Tg * top_k + E - 1) // E) * capacity_factor), 8)
    C = min(C, Tg * top_k)

    xg = x.reshape(G, Tg, d)
    logits = xg.astype(jnp.float32) @ p["router"]               # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)          # [G, Tg, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # ---- dispatch (batched over groups) ----
    flat_expert = expert_ids.reshape(G, Tg * top_k)
    flat_token = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg), top_k)[None, :], (G, Tg * top_k))
    flat_gate = gate_vals.reshape(G, Tg * top_k)
    order = jnp.argsort(flat_expert, axis=1, stable=True)
    sorted_expert = jnp.take_along_axis(flat_expert, order, axis=1)
    sorted_token = jnp.take_along_axis(flat_token, order, axis=1)
    sorted_gate = jnp.take_along_axis(flat_gate, order, axis=1)
    first_pos = jax.vmap(
        lambda se: jnp.searchsorted(se, se, side="left"))(sorted_expert)
    pos_in_group = jnp.arange(Tg * top_k)[None, :] - first_pos
    keep = pos_in_group < C
    slot = jnp.where(keep, sorted_expert * C + pos_in_group, E * C)

    gathered = jnp.take_along_axis(xg, sorted_token[:, :, None], axis=1)
    gathered = jnp.where(keep[:, :, None], gathered, 0.0)
    buf = jnp.zeros((G, E * C + 1, d), x.dtype)
    buf = jax.vmap(lambda b, s, v: b.at[s].set(v))(buf, slot, gathered)
    tiles = constrain(buf[:, : E * C].reshape(G, E, C, d), "expert_tiles")

    # ---- expert computation ----
    g = jnp.einsum("gecd,edf->gecf", tiles, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", tiles, p["w_up"])
    h = constrain(jax.nn.silu(g) * u, "expert_hidden")
    y = constrain(jnp.einsum("gecf,efd->gecd", h, p["w_down"]),
                  "expert_tiles").reshape(G, E * C, d)

    # ---- combine ----
    picked = jnp.take_along_axis(
        y, jnp.minimum(slot, E * C - 1)[:, :, None], axis=1)
    contrib = jnp.where(keep[:, :, None],
                        picked * sorted_gate[:, :, None], 0.0).astype(x.dtype)
    out = jax.vmap(lambda t, c: jnp.zeros((Tg, d), x.dtype).at[t].add(c))(
        sorted_token, contrib)
    out = constrain(out.reshape(T, d), "tokens_2d")

    # ---- aux losses ----
    me = jnp.mean(probs, axis=(0, 1))                            # [E]
    one_hot_top1 = jax.nn.one_hot(expert_ids[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=(0, 1))
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    frac_dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "frac_dropped": frac_dropped}
    return out, aux
