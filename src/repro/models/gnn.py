"""Graph attention network (GAT, Veličković et al. 2018) via segment ops.

JAX has no sparse-matrix message passing beyond BCOO, so (per the assignment
notes) the SpMM/SDDMM regime is built from first principles on an edge list:

  SDDMM  — per-edge attention logits  e_ij = LeakyReLU(a_src·h_i + a_dst·h_j)
  segment-softmax over destination    α_ij = exp(e_ij − max_j) / Σ_j
  SpMM   — message aggregation        h'_j = Σ_i α_ij · h_i      (segment_sum)

Edge-parallel distribution: edges are sharded across devices inside
``shard_map``; each shard computes partial segment reductions over the full
node range and the three reductions (max, normalizer, weighted sum) are
combined with ``pmax`` / ``psum`` — the roofline's collective term for the
``ogb_products`` cell comes from exactly these three collectives.

Supports the 4 assigned shapes: full-graph (cora), sampled minibatch
(fanout subgraph, padded), full-batch-large (ogb_products), and batched
small graphs (molecule; block-diagonal edge list + graph readout).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .common import dense_init
from .hints import constrain


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat"
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 8
    n_heads: int = 8
    n_classes: int = 7
    negative_slope: float = 0.2
    readout: Optional[str] = None      # None (node-level) | "mean" (graph-level)
    dtype: Any = jnp.float32

    @property
    def layer_dims(self) -> list[tuple[int, int]]:
        dims = []
        d = self.d_in
        for i in range(self.n_layers):
            out = self.d_hidden if i < self.n_layers - 1 else self.n_classes
            dims.append((d, out))
            d = out * self.n_heads if i < self.n_layers - 1 else out
        return dims


def init(cfg: GATConfig, key) -> dict:
    params = {}
    for i, (d_in, d_out) in enumerate(cfg.layer_dims):
        k1, k2, k3 = jax.random.split(jax.random.fold_in(key, i), 3)
        params[f"layer{i}"] = {
            "w": dense_init(k1, d_in, cfg.n_heads * d_out, cfg.dtype),
            "a_src": dense_init(k2, cfg.n_heads, d_out, cfg.dtype),
            "a_dst": dense_init(k3, cfg.n_heads, d_out, cfg.dtype),
            "b": jnp.zeros((cfg.n_heads * d_out,), cfg.dtype),
        }
    return params


def _gat_layer(p: dict, x: jax.Array, src: jax.Array, dst: jax.Array,
               edge_mask: jax.Array, n_nodes: int, n_heads: int,
               slope: float, mean_heads: bool,
               axis_name: Optional[str] = None) -> jax.Array:
    """One GAT layer over an edge list (optionally edge-sharded on
    ``axis_name``; partial segment reductions are psum/pmax-combined)."""
    H = n_heads
    # node tensors shard heads over 'model' (hint "gnn_nodes_hd"); edge
    # tensors shard edges over the dp axes (hint via input shardings) —
    # the full-batch-large cell otherwise replicates ~0.6 GB per [N, H, d]
    # node buffer on every device.
    h = constrain((x @ p["w"]).reshape(x.shape[0], H, -1), "gnn_nodes_hd")
    s_src = jnp.einsum("nhd,hd->nh", h, p["a_src"])          # [N, H]
    s_dst = jnp.einsum("nhd,hd->nh", h, p["a_dst"])
    s_src = constrain(s_src, "gnn_nodes_h")
    s_dst = constrain(s_dst, "gnn_nodes_h")
    src_c = jnp.where(src >= 0, src, 0)
    dst_c = jnp.where(dst >= 0, dst, 0)
    e = s_src[src_c] + s_dst[dst_c]                          # [E, H]
    e = jax.nn.leaky_relu(e, slope)
    e = jnp.where(edge_mask[:, None], e, -jnp.inf)
    e = constrain(e, "gnn_edges_h")

    # segment-softmax over dst (numerically stable; max is gradient-stopped)
    seg_max = jax.ops.segment_max(e, dst_c, num_segments=n_nodes)
    if axis_name:
        seg_max = jax.lax.pmax(seg_max, axis_name)
    seg_max = jax.lax.stop_gradient(
        jnp.where(jnp.isfinite(seg_max), seg_max, 0.0))
    seg_max = constrain(seg_max, "gnn_nodes_h")
    z = jnp.exp(e - seg_max[dst_c])
    z = jnp.where(edge_mask[:, None], z, 0.0)
    denom = jax.ops.segment_sum(z, dst_c, num_segments=n_nodes)
    if axis_name:
        denom = jax.lax.psum(denom, axis_name)
    denom = constrain(denom, "gnn_nodes_h")
    msg = z[:, :, None] * h[src_c]                           # [E, H, d]
    agg = jax.ops.segment_sum(msg, dst_c, num_segments=n_nodes)
    if axis_name:
        agg = jax.lax.psum(agg, axis_name)
    agg = constrain(agg, "gnn_nodes_hd")
    out = agg / jnp.maximum(denom[:, :, None], 1e-9)
    if mean_heads:
        return jnp.mean(out, axis=1)                         # final layer
    out = jax.nn.elu(out)
    return out.reshape(x.shape[0], -1) + p["b"]


def forward(cfg: GATConfig, params: dict, x: jax.Array, src: jax.Array,
            dst: jax.Array, edge_mask: Optional[jax.Array] = None,
            axis_name: Optional[str] = None) -> jax.Array:
    """x f32[N, d_in]; src/dst int32[E] (−1 = padding) → logits.

    Node-level: [N, n_classes].  With cfg.readout == "mean" callers follow
    with ``graph_readout``.
    """
    if edge_mask is None:
        edge_mask = src >= 0
    n_nodes = x.shape[0]
    for i in range(cfg.n_layers):
        p = params[f"layer{i}"]
        last = i == cfg.n_layers - 1
        x = _gat_layer(p, x, src, dst, edge_mask, n_nodes, cfg.n_heads,
                       cfg.negative_slope, mean_heads=last,
                       axis_name=axis_name)
    return x


def graph_readout(node_logits: jax.Array, graph_ids: jax.Array,
                  n_graphs: int, node_mask: jax.Array) -> jax.Array:
    """Mean-pool node representations per graph (molecule cell)."""
    gid = jnp.where(node_mask, graph_ids, n_graphs)
    summed = jax.ops.segment_sum(
        jnp.where(node_mask[:, None], node_logits, 0.0), gid,
        num_segments=n_graphs + 1)[:n_graphs]
    counts = jax.ops.segment_sum(node_mask.astype(jnp.float32), gid,
                                 num_segments=n_graphs + 1)[:n_graphs]
    return summed / jnp.maximum(counts[:, None], 1.0)


def loss_fn(cfg: GATConfig, params: dict, x, src, dst, labels,
            label_mask, axis_name: Optional[str] = None,
            graph_ids: Optional[jax.Array] = None,
            n_graphs: int = 0,
            node_mask: Optional[jax.Array] = None):
    logits = forward(cfg, params, x, src, dst, axis_name=axis_name)
    if cfg.readout == "mean":
        logits = graph_readout(logits, graph_ids, n_graphs, node_mask)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    nll = jnp.where(label_mask, nll, 0.0)
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(label_mask), 1.0)
    acc = jnp.sum(jnp.where(label_mask, (jnp.argmax(logits, -1) == labels), 0.0)) \
        / jnp.maximum(jnp.sum(label_mask), 1.0)
    return loss, {"acc": acc}
