"""Decoder-only transformer LM — dense and MoE variants, scan-over-layers.

Covers all five assigned LM architectures through one config dataclass:
RMSNorm · RoPE · GQA · SwiGLU · optional MoE (top-k, shared experts,
periodic MoE placement) · optional sliding-window attention per layer
(llama4-style iRoPE hybrid: window layers + periodic full/global layers).

Layer parameters are stacked [L, ...] so the forward pass is a single
``lax.scan`` — this keeps HLO size O(1) in depth (essential for compiling
48-layer dry-runs) and gives the remat policy one clean boundary.

Entry points (all pure functions over a params pytree):
  init(cfg, key)                            → params
  forward(cfg, params, tokens)              → logits         (training)
  prefill(cfg, params, tokens)              → logits, kv-cache
  decode_step(cfg, params, cache, tok, pos) → logits, cache  (serving)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .common import (
    apply_rope,
    decode_attention,
    dense_init,
    flash_attention,
    rms_norm,
    swiglu,
)
from .hints import constrain
from .moe import moe_apply, moe_init


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 512                  # dense FFN width / per-expert width
    vocab: int = 1024
    head_dim: Optional[int] = None   # default d_model // n_heads
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0               # 0 → dense model
    top_k: int = 1
    n_shared_experts: int = 0        # DeepSeek/Moonlight-style shared experts
    moe_period: int = 1              # every p-th layer is MoE (llama4: 2)
    first_dense: int = 0             # leading dense layers (moonlight: 1)
    capacity_factor: float = 1.25
    # attention pattern
    window: Optional[int] = None     # sliding-window size for window layers
    window_period: int = 0           # 0 → all layers full attention;
                                     # p → layers with (i % p != p-1) use window
    dispatch_groups: int = 1         # MoE dispatch groups (launchers: dp size)
    dtype: Any = jnp.bfloat16
    # loss weights
    lb_coef: float = 0.01
    z_coef: float = 1e-3

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def n_scan_layers(self) -> int:
        return self.n_layers - self.first_dense

    def param_count(self) -> int:
        d, hd, H, KV = self.d_model, self.hd, self.n_heads, self.n_kv_heads
        attn = d * (H + 2 * KV) * hd + H * hd * d + 2 * d
        dense_ffn = 3 * d * self.d_ff
        moe_ffn = (self.n_experts * 3 * d * self.d_ff
                   + self.n_shared_experts * 3 * d * self.d_ff
                   + d * self.n_experts)
        n_moe = 0
        if self.is_moe:
            n_moe = sum(1 for i in range(self.first_dense, self.n_layers)
                        if (i - self.first_dense) % self.moe_period == self.moe_period - 1)
        n_dense = self.n_layers - n_moe
        return (self.n_layers * attn + n_dense * dense_ffn + n_moe * moe_ffn
                + 2 * self.vocab * d + d)

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        moe_total = self.n_experts * 3 * d * self.d_ff
        moe_active = (self.top_k + self.n_shared_experts) * 3 * d * self.d_ff
        n_moe = sum(1 for i in range(self.first_dense, self.n_layers)
                    if (i - self.first_dense) % self.moe_period == self.moe_period - 1)
        return full - n_moe * (moe_total - moe_active)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(cfg: LMConfig, key, moe_layer: bool) -> dict:
    d, hd, H, KV = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    p = {
        "ln1": jnp.ones((d,), jnp.float32),
        "wq": dense_init(ks[0], d, H * hd, cfg.dtype),
        "wk": dense_init(ks[1], d, KV * hd, cfg.dtype),
        "wv": dense_init(ks[2], d, KV * hd, cfg.dtype),
        "wo": dense_init(ks[3], H * hd, d, cfg.dtype),
        "ln2": jnp.ones((d,), jnp.float32),
    }
    if moe_layer:
        p["moe"] = moe_init(ks[4], d, cfg.d_ff, cfg.n_experts, cfg.dtype)
        if cfg.n_shared_experts:
            ff_sh = cfg.n_shared_experts * cfg.d_ff
            p["shared"] = {
                "w_gate": dense_init(ks[5], d, ff_sh, cfg.dtype),
                "w_up": dense_init(ks[6], d, ff_sh, cfg.dtype),
                "w_down": dense_init(ks[7], ff_sh, d, cfg.dtype),
            }
    else:
        p["ffn"] = {
            "w_gate": dense_init(ks[5], d, cfg.d_ff, cfg.dtype),
            "w_up": dense_init(ks[6], d, cfg.d_ff, cfg.dtype),
            "w_down": dense_init(ks[7], cfg.d_ff, d, cfg.dtype),
        }
    return p


def _is_moe_layer(cfg: LMConfig, i: int) -> bool:
    if not cfg.is_moe or i < cfg.first_dense:
        return False
    return (i - cfg.first_dense) % cfg.moe_period == cfg.moe_period - 1


def init(cfg: LMConfig, key) -> dict:
    """Stacked params.  Scan block covers layers [first_dense, n_layers); if
    the MoE placement is periodic the scan body processes ``moe_period``
    layers (period−1 dense + 1 MoE) so the stack stays uniform."""
    keys = jax.random.split(key, cfg.n_layers + 3)
    params: dict = {
        "embed": dense_init(keys[0], cfg.vocab, cfg.d_model, cfg.dtype, scale=0.02),
        "unembed": dense_init(keys[1], cfg.d_model, cfg.vocab, cfg.dtype),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
    }
    # leading dense layers (unrolled)
    params["head_layers"] = [
        _layer_init(cfg, keys[2 + i], moe_layer=False)
        for i in range(cfg.first_dense)
    ]
    # scanned stack
    n_scan = cfg.n_scan_layers
    if cfg.is_moe:
        period = cfg.moe_period
        assert n_scan % period == 0, (
            f"{cfg.name}: scan layers {n_scan} not divisible by moe_period {period}")
        n_super = n_scan // period
        sub = []
        for j in range(period):
            moe_layer = (j == period - 1)
            stack = [
                _layer_init(cfg, keys[2 + cfg.first_dense + s * period + j], moe_layer)
                for s in range(n_super)
            ]
            sub.append(jax.tree.map(lambda *xs: jnp.stack(xs), *stack))
        params["scan"] = sub            # list of length `period`
    else:
        stack = [
            _layer_init(cfg, keys[2 + cfg.first_dense + s], moe_layer=False)
            for s in range(n_scan)
        ]
        params["scan"] = [jax.tree.map(lambda *xs: jnp.stack(xs), *stack)]
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _attn(cfg: LMConfig, p: dict, x: jax.Array, positions: jax.Array,
          layer_window: Optional[int]) -> jax.Array:
    B, S, d = x.shape
    h = rms_norm(x, p["ln1"])
    q = constrain((h @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.hd), "act_heads")
    k = constrain((h @ p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd), "act_kv")
    v = constrain((h @ p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd), "act_kv")
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, causal=True, window=layer_window)
    return x + constrain(
        o.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"], "act_3d")


def _ffn_dense(p: dict, x: jax.Array) -> jax.Array:
    h = rms_norm(x, p["ln2"])
    g = constrain(jnp.einsum("...d,df->...f", h, p["ffn"]["w_gate"]), "act_ff")
    u = constrain(jnp.einsum("...d,df->...f", h, p["ffn"]["w_up"]), "act_ff")
    out = jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, p["ffn"]["w_down"])
    return x + constrain(out, "act_3d")


def _ffn_moe(cfg: LMConfig, p: dict, x: jax.Array) -> tuple[jax.Array, dict]:
    B, S, d = x.shape
    h = rms_norm(x, p["ln2"])
    flat = h.reshape(B * S, d)
    out, aux = moe_apply(p["moe"], flat, cfg.top_k, cfg.capacity_factor,
                         n_groups=cfg.dispatch_groups)
    out = out.reshape(B, S, d)
    if cfg.n_shared_experts:
        sh = p["shared"]
        out = out + swiglu(h, sh["w_gate"], sh["w_up"], sh["w_down"])
    return x + out, aux


def _layer_window(cfg: LMConfig, layer_idx: int) -> Optional[int]:
    if cfg.window is None or cfg.window_period == 0:
        return None
    if layer_idx % cfg.window_period == cfg.window_period - 1:
        return None        # periodic global layer
    return cfg.window


def forward(cfg: LMConfig, params: dict, tokens: jax.Array,
            remat: bool = True) -> tuple[jax.Array, dict]:
    """tokens int32[B, S] → (logits f32[B, S, V], aux)."""
    B, S = tokens.shape
    x = constrain(jnp.take(params["embed"], tokens, axis=0), "act_3d")
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    aux_acc = {"lb_loss": jnp.float32(0), "z_loss": jnp.float32(0),
               "frac_dropped": jnp.float32(0)}

    for i, p in enumerate(params["head_layers"]):
        x = _attn(cfg, p, x, positions, _layer_window(cfg, i))
        x = _ffn_dense(p, x)

    period = cfg.moe_period if cfg.is_moe else 1
    n_super = cfg.n_scan_layers // period

    def super_layer(carry, layer_params):
        x, aux = carry
        x = constrain(x, "act_3d")
        for j, p in enumerate(layer_params):
            # window pattern is uniform across the scan (same offset per
            # sub-layer position) — matches llama4's fixed interleave
            w = cfg.window if (cfg.window is not None and cfg.window_period
                               and j % cfg.window_period != cfg.window_period - 1) else None
            x = _attn(cfg, p, x, positions, w)
            if cfg.is_moe and j == period - 1:
                x, a = _ffn_moe(cfg, p, x)
                aux = {k: aux[k] + a[k] for k in aux}
            else:
                x = _ffn_dense(p, x)
        return (x, aux), None

    body = super_layer
    if remat:
        body = jax.checkpoint(super_layer, prevent_cse=False)

    (x, aux_acc), _ = jax.lax.scan(
        lambda c, ps: body(c, ps), (x, aux_acc), tuple(params["scan"]),
        length=n_super)

    x = rms_norm(x, params["ln_f"])
    logits = constrain((x @ params["unembed"]).astype(jnp.float32), "logits")
    n_moe = max(sum(1 for i in range(cfg.n_layers) if _is_moe_layer(cfg, i)), 1)
    aux = {k: v / n_moe for k, v in aux_acc.items()}
    return logits, aux


def loss_fn(cfg: LMConfig, params: dict, tokens: jax.Array,
            targets: jax.Array) -> tuple[jax.Array, dict]:
    logits, aux = forward(cfg, params, tokens)
    # Vocab-parallel-safe cross entropy: no gather along V (a
    # take_along_axis over a 'model'-sharded vocab axis would force XLA to
    # all-gather the full [B,S,V] logits — the one-hot contraction and the
    # logsumexp both partition cleanly instead).
    lse = jax.nn.logsumexp(logits, axis=-1)                       # [B, S]
    one_hot = (jnp.arange(cfg.vocab, dtype=targets.dtype)[None, None, :]
               == targets[..., None])
    tgt_logit = jnp.sum(jnp.where(one_hot, logits, 0.0), axis=-1)
    nll = lse - tgt_logit
    loss = jnp.mean(nll)
    if cfg.is_moe:
        loss = loss + cfg.lb_coef * aux["lb_loss"] + cfg.z_coef * aux["z_loss"]
    return loss, {"nll": jnp.mean(nll), **aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=None) -> dict:
    """KV cache pytree: one [L, B, S, KV, hd] pair per scan sub-stack plus
    per-head-layer caches."""
    dtype = dtype or cfg.dtype
    KV, hd = cfg.n_kv_heads, cfg.hd
    period = cfg.moe_period if cfg.is_moe else 1
    n_super = cfg.n_scan_layers // period

    def kv(n):
        return {
            "k": jnp.zeros((n, batch, max_seq, KV, hd), dtype),
            "v": jnp.zeros((n, batch, max_seq, KV, hd), dtype),
        }

    return {
        "head": [kv(1) for _ in range(cfg.first_dense)],
        "scan": [kv(n_super) for _ in range(period)],
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def _attn_decode(cfg: LMConfig, p: dict, x: jax.Array, k_cache, v_cache,
                 pos: jax.Array, window: Optional[int]):
    """x [B, 1, d]; returns (out [B, 1, d], new_k_entry, new_v_entry)."""
    B = x.shape[0]
    k_cache = constrain(k_cache, "cache_kv")
    v_cache = constrain(v_cache, "cache_kv")
    h = rms_norm(x[:, 0, :], p["ln1"])
    q = (h @ p["wq"]).reshape(B, cfg.n_heads, cfg.hd)
    k = (h @ p["wk"]).reshape(B, cfg.n_kv_heads, cfg.hd)
    v = (h @ p["wv"]).reshape(B, cfg.n_kv_heads, cfg.hd)
    # q is tiny (one token); replicating it over 'model' lets the score
    # einsum contract against the hd-sharded cache *locally* (partial sums
    # + a 50 MB psum of scores) — leaving q head-sharded makes XLA
    # all-gather the multi-GB cache to reshard hd→heads every layer.
    q = constrain(apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0],
                  "decode_q")
    k = apply_rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    # write new kv at pos
    k_cache = constrain(jax.vmap(
        lambda c, kk, pp: jax.lax.dynamic_update_slice_in_dim(
            c, kk[None], pp, axis=0))(k_cache, k, pos), "cache_kv")
    v_cache = constrain(jax.vmap(
        lambda c, vv, pp: jax.lax.dynamic_update_slice_in_dim(
            c, vv[None], pp, axis=0))(v_cache, v, pos), "cache_kv")
    o = decode_attention(q, k_cache, v_cache, pos + 1, window=window)
    out = x + (o.reshape(B, cfg.n_heads * cfg.hd) @ p["wo"])[:, None, :]
    return out, k_cache, v_cache


def decode_step(cfg: LMConfig, params: dict, cache: dict,
                tokens: jax.Array) -> tuple[jax.Array, dict]:
    """One serving step: tokens int32[B] (current token) → next-token logits
    [B, V]; cache advanced functionally.

    The stacked KV cache rides in the scan *carry* and is updated with
    dynamic_update_index — XLA's while-loop buffer aliasing then keeps the
    multi-GB cache in place.  (Routing the per-layer cache through scan ys
    materializes a second full cache: +12 GiB/device on the 16B-MoE
    decode_32k cell.)"""
    B = tokens.shape[0]
    pos = cache["pos"]
    x = constrain(jnp.take(params["embed"], tokens, axis=0)[:, None, :],
                  "act_3d")   # [B, 1, d]

    new_head = []
    for i, p in enumerate(params["head_layers"]):
        c = cache["head"][i]
        x, kc, vc = _attn_decode(cfg, p, x, c["k"][0], c["v"][0], pos,
                                 _layer_window(cfg, i))
        new_head.append({"k": kc[None], "v": vc[None]})
        x = _ffn_dense(p, x)

    period = cfg.moe_period if cfg.is_moe else 1
    n_super = cfg.n_scan_layers // period

    def super_layer(carry, inp):
        x, caches = carry
        i, layer_params = inp
        new_caches = []
        for j in range(period):
            p = layer_params[j]
            ck = jax.lax.dynamic_index_in_dim(caches[j]["k"], i, axis=0,
                                              keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(caches[j]["v"], i, axis=0,
                                              keepdims=False)
            w = cfg.window if (cfg.window is not None and cfg.window_period
                               and j % cfg.window_period != cfg.window_period - 1) else None
            x, kc, vc = _attn_decode(cfg, p, x, ck, cv, pos, w)
            new_caches.append({
                "k": jax.lax.dynamic_update_index_in_dim(
                    caches[j]["k"], kc, i, axis=0),
                "v": jax.lax.dynamic_update_index_in_dim(
                    caches[j]["v"], vc, i, axis=0),
            })
            if cfg.is_moe and j == period - 1:
                x, _ = _ffn_moe(cfg, p, x)
            else:
                x = _ffn_dense(p, x)
        return (x, tuple(new_caches)), None

    (x, new_scan), _ = jax.lax.scan(
        super_layer, (x, tuple(cache["scan"])),
        (jnp.arange(n_super), tuple(params["scan"])), length=n_super)

    x = rms_norm(x[:, 0, :], params["ln_f"])
    logits = (x @ params["unembed"]).astype(jnp.float32)
    new_cache = {
        "head": new_head,
        "scan": list(new_scan),
        "pos": pos + 1,
    }
    return logits, new_cache


def prefill(cfg: LMConfig, params: dict, tokens: jax.Array) -> jax.Array:
    """Prefill = full forward over the prompt; returns last-position logits.
    (Cache materialization for subsequent decode is exercised separately by
    decode_step; the prefill dry-run measures the compute-bound pass.)"""
    logits, _ = forward(cfg, params, tokens)
    return logits[:, -1, :]
