"""Train-step factory: loss → grad → clip → AdamW, with optional microbatch
gradient accumulation (scan) and donation-friendly packing.

The returned step is a pure function
    step(state: TrainState, batch) → (state, metrics)
suitable for ``jax.jit(..., in_shardings=..., donate_argnums=0)`` — the
launchers in ``repro.launch`` attach the mesh/shardings; nothing here is
mesh-aware, which is what keeps the same step usable for smoke tests
(1 CPU device) and the 512-chip dry-run.

Gradient communication notes (DESIGN.md §6): with bf16 params the backward
all-reduces run in bf16 already (2× wire compression vs f32); microbatch
accumulation holds an f32 accumulator so precision is recovered at the
accumulation boundary.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim import OptConfig, adamw_init, adamw_update
from repro.core.types import _register, static_field


@_register
@dataclasses.dataclass(frozen=True)
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array

    @staticmethod
    def create(params, opt_cfg: OptConfig) -> "TrainState":
        return TrainState(params=params, opt_state=adamw_init(params, opt_cfg),
                          step=jnp.zeros((), jnp.int32))


def make_train_step(
    loss_fn: Callable,            # loss_fn(params, batch) → (loss, metrics)
    opt_cfg: OptConfig,
    accum_steps: int = 1,
    accum_dtype=None,
) -> Callable:
    """Build the jit-able train step.  With accum_steps > 1 the batch's
    leading axis must be [accum_steps, micro_batch, ...]; gradients are
    accumulated across a lax.scan before one optimizer update.

    ``accum_dtype`` controls the accumulator precision: f32 (default) is
    exact; param-dtype (bf16) halves the accumulator footprint — at 400B
    params that is 3.1 GiB/device of HBM (the wire all-reduces are bf16
    either way; stochastic-rounding-free bf16 accumulation over ≤16
    microbatches loses <0.5 ulp in practice)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, metrics, grads

    def step(state: TrainState, batch):
        if accum_steps == 1:
            loss, metrics, grads = grads_of(state.params, batch)
        else:
            adt = accum_dtype or jnp.float32

            def micro(acc, mb):
                loss_a, g_acc = acc
                loss, metrics, grads = grads_of(state.params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype) / accum_steps,
                    g_acc, grads)
                return (loss_a + loss / accum_steps, g_acc), metrics

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt if p.dtype == jnp.bfloat16
                                    else jnp.float32), state.params)
            (loss, grads), metrics_all = jax.lax.scan(
                micro, (jnp.float32(0), g0), batch)
            metrics = jax.tree.map(lambda m: m[-1], metrics_all)
            grads = jax.tree.map(
                lambda g, p: g.astype(p.dtype), grads, state.params)

        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt_state, state.params, opt_cfg)
        new_state = TrainState(params=new_params, opt_state=new_opt,
                               step=state.step + 1)
        return new_state, {"loss": loss, **metrics, **opt_metrics}

    return step
