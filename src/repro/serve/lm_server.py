"""LM serving: greedy/temperature generation over the KV-cache decode step."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tf


def generate(cfg: tf.LMConfig, params: dict, prompt: jax.Array,
             max_new: int = 32, max_seq: int = 256,
             temperature: float = 0.0, key: Optional[jax.Array] = None
             ) -> jax.Array:
    """prompt int32[B, P] → tokens int32[B, P + max_new] (greedy if T=0)."""
    B, P = prompt.shape
    cache = tf.init_cache(cfg, B, max_seq)

    # prefill by stepping through the prompt (simple and exact; the batched
    # prefill kernel path is exercised by the prefill dry-run shapes)
    def prefill_step(carry, t):
        cache, _ = carry
        logits, cache = tf.decode_step(cfg, params, cache, prompt[:, t])
        return (cache, logits), None

    (cache, logits), _ = jax.lax.scan(
        prefill_step, (cache, jnp.zeros((B, cfg.vocab), jnp.float32)),
        jnp.arange(P))

    def sample(logits, k):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, logits / temperature).astype(jnp.int32)

    key = key if key is not None else jax.random.PRNGKey(0)

    def step(carry, k):
        cache, tok = carry
        logits, cache = tf.decode_step(cfg, params, cache, tok)
        nxt = sample(logits, k)
        return (cache, nxt), nxt

    first = sample(logits, key)
    (_, _), toks = jax.lax.scan(step, (cache, first),
                                jax.random.split(key, max_new - 1))
    return jnp.concatenate([prompt, first[:, None], toks.T], axis=1)
