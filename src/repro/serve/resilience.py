"""Resilience layer for ANN serving: admission control, per-request
deadlines, an error-bounded degradation ladder, and failure containment.

δ-EMG makes *principled* degradation possible.  A recall-tuned index that
shrinks its search budget under load returns arbitrarily bad results; a
δ-monotonic graph does not — any greedy search converges to a
``(1/δ)``-approximate neighbor, and the adaptive α-stop rule (Alg. 3)
tightens that to ``1/(δ·α)``.  So the ladder here trades *bound* for
*latency* along a known curve: each rung steps ``l_max`` / ``beam_width``
down and relaxes the adaptive δ-target (α → 1) under queue pressure, and
every response reports the approximation factor it was served under.

Containment layers, outermost first:

1. **Admission control** — ``submit`` sheds requests beyond ``max_queue``
   (terminal ``status="shed"`` response, never an exception).
2. **Per-request validation** — shape/dtype/NaN/Inf checks reject a bad
   query *individually* instead of poisoning its whole batch.
3. **Deadlines** — requests already past their deadline at dispatch are
   answered with ``status="deadline"`` instead of burning search budget;
   requests that complete late are flagged ``deadline_missed``.
4. **Retry with backoff** — transient search faults are retried on the
   same tier before the breaker reacts.
5. **Circuit breaker** — repeated faults open the tier and fall back down
   the chain ``(beam, pallas) → (beam, jnp) → (beam, jnp, W=1)``; after a
   cooldown the tier is probed again (half-open) and closes on success.
   The chain bottoms out at ``(beam, jnp, W=1)`` — greedy best-first on
   the same lock-step engine, the minimal configuration that still
   carries the ``1/(δ·α)`` guarantee.  Exhausting every tier raises
   ``SearchFailure`` inside the containment, which ``drain()`` converts
   to per-request ``status="failed"`` responses — never a crash, and
   never a hidden fallback engine.

Everything is single-threaded and deterministically testable: the breaker
takes an injectable clock and the fault harness (``repro.testing.faults``)
wraps the one seam every batch passes through (``AnnServer._search``).

Observability (``metrics=`` / ``tracer=``, inherited from ``AnnServer``):
on top of the base serve taxonomy, the resilience layer emits *structured
transition events* — every degradation-ladder step records
``serve_degradation_transition`` (rung, direction, queue-depth reason, and
the ``1/(δ·α)`` bound now in force) and every circuit-breaker tier move
records ``serve_breaker_transition`` (from/to tier) — alongside labeled
counters (``serve_degradation_transitions_total{direction,rung}``,
``serve_breaker_transitions_total{from,to}``) and a ``serve_rung`` gauge,
so the blind spots the ad-hoc ``ServeStats`` totals left (when did we
degrade, why, under what bound) are first-class telemetry.  All clocks are
monotonic (``obs.Timer``); deadlines are absolute ``perf_counter``
instants.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import EMQGIndex, SearchParams, SearchResult
from repro.obs import Timer

from .ann_server import AnnServer, _Request


# ---------------------------------------------------------------------------
# Per-request validation.
# ---------------------------------------------------------------------------


def validate_query(query, dim: int) -> Optional[str]:
    """Return a rejection reason, or None if the query is servable."""
    try:
        q = np.asarray(query)
    except Exception as e:                      # ragged / unconvertible input
        return f"unconvertible query: {e}"
    if q.dtype == object:
        return f"unconvertible query dtype: {q.dtype}"
    if not (np.issubdtype(q.dtype, np.floating)
            or np.issubdtype(q.dtype, np.integer)):
        return f"non-numeric query dtype: {q.dtype}"
    if q.ndim != 1:
        return f"expected a rank-1 query, got shape {q.shape}"
    if q.shape[0] != dim:
        return f"query dim {q.shape[0]} != index dim {dim}"
    if not np.all(np.isfinite(q)):
        return "query contains non-finite values (NaN/Inf)"
    return None


# ---------------------------------------------------------------------------
# Error-bounded degradation ladder.
# ---------------------------------------------------------------------------


class DegradationLadder:
    """Rungs of ``SearchParams`` from full quality (rung 0) down.

    Rung ``r`` halves ``l_max`` (floor ``k``) and ``beam_width`` (floor 1)
    per step and, for adaptive search, decays the α margin toward 1
    (``α_r = 1 + (α₀−1)·2^{−r}`` — α→1 stops the adaptive widening sooner,
    i.e. relaxes the δ-target).  ``delta_bound(r)`` is the approximation
    factor the paper guarantees for that rung: returned distances are
    within ``1/(δ·α_r)`` of the true k-NN distance (``1/δ`` for
    non-adaptive greedy search), finite whenever the construction δ is
    known — which is exactly what makes shedding *quality* safer than
    shedding *requests* on this index family.
    """

    def __init__(self, base: SearchParams, delta: float, n_rungs: int = 4):
        if n_rungs < 1:
            raise ValueError(f"n_rungs must be ≥ 1, got {n_rungs}")
        self.delta = float(delta)
        self._rungs: list[SearchParams] = []
        for r in range(n_rungs):
            l_max = max(base.k, base.l_max >> r)
            self._rungs.append(dataclasses.replace(
                base,
                l_max=l_max,
                l0=min(base.l0, l_max),
                beam_width=max(1, base.beam_width >> r),
                alpha=1.0 + (base.alpha - 1.0) * (0.5 ** r)
                if base.adaptive else base.alpha,
            ))

    def __len__(self) -> int:
        return len(self._rungs)

    def params(self, rung: int) -> SearchParams:
        return self._rungs[min(max(rung, 0), len(self._rungs) - 1)]

    def delta_bound(self, rung: int) -> float:
        """Approximation factor at ``rung``; ``inf`` if δ is unknown (≤ 0)."""
        if self.delta <= 0.0:
            return math.inf
        p = self.params(rung)
        alpha = p.alpha if p.adaptive else 1.0
        return 1.0 / (self.delta * max(alpha, 1.0))


# ---------------------------------------------------------------------------
# Circuit breaker over (engine, backend) tiers.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Tier:
    engine: str
    backend: str
    beam_width: Optional[int] = None    # pin W for this tier (None → ladder's)
    failures: int = 0
    open_until: float = 0.0

    @property
    def name(self) -> str:
        base = f"{self.engine}/{self.backend}"
        return base if self.beam_width is None else f"{base}/w{self.beam_width}"


class CircuitBreaker:
    """Fall-back chain of search tiers with per-tier failure tracking.

    A tier is CLOSED while its consecutive-failure count is below
    ``threshold``; at the threshold it OPENs for ``cooldown_s`` and
    ``current()`` moves down the chain.  After the cooldown the tier is
    HALF_OPEN: it is offered again, a success closes it (count reset), a
    failure re-opens it for another cooldown.  The last tier never opens —
    the server always has *something* to run a batch on.
    """

    def __init__(self, tiers: list[tuple[str, str]], threshold: int = 3,
                 cooldown_s: float = 30.0, clock=time.monotonic):
        if not tiers:
            raise ValueError("breaker needs at least one tier")
        self.tiers = [_Tier(*t) for t in tiers]
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self.clock = clock

    def current(self) -> tuple[int, _Tier]:
        now = self.clock()
        for i, t in enumerate(self.tiers):
            if t.failures < self.threshold or now >= t.open_until:
                return i, t
        return len(self.tiers) - 1, self.tiers[-1]

    def record_success(self, i: int) -> None:
        self.tiers[i].failures = 0
        self.tiers[i].open_until = 0.0

    def record_failure(self, i: int) -> None:
        t = self.tiers[i]
        t.failures += 1
        if t.failures >= self.threshold:
            t.open_until = self.clock() + self.cooldown_s


def default_tiers(engine: str, backend: str) -> list[tuple]:
    """Primary tier as configured, then the portable jnp backend, then
    ``(beam, jnp, W=1)`` — greedy best-first on the production engine, the
    minimal tier that still carries the δ-EMG bound.  That is the bottom:
    past it the batch fails loudly (``SearchFailure``), it does not reach
    for another engine."""
    chain = [(engine, backend, None)]
    if engine == "beam" and backend != "jnp":
        chain.append(("beam", "jnp", None))
    chain.append(("beam", "jnp", 1))
    seen, out = set(), []
    for t in chain:
        if t not in seen:
            seen.add(t)
            out.append(t)
    return out


# ---------------------------------------------------------------------------
# The resilient server.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    max_queue: int = 4096               # admission control: shed beyond this
    deadline_s: Optional[float] = None  # default per-request deadline
    degrade_depth: int = 64             # queue depth that trips one rung down
    recover_depth: int = 8              # queue depth that climbs one rung up
    n_rungs: int = 4
    max_retries: int = 2                # per batch, before declaring failure
    backoff_s: float = 0.02             # base retry backoff (doubles per try)
    backoff_cap_s: float = 1.0
    breaker_threshold: int = 3          # consecutive faults to open a tier
    breaker_cooldown_s: float = 30.0
    delta: Optional[float] = None       # override index δ for bound reporting


@dataclasses.dataclass
class Response:
    """Per-request outcome.  ``status``:

    * ``ok``       — served; ``ids``/``dists`` valid, ``delta_bound`` is the
      approximation factor of the rung it was served at (``saturated=True``
      marks queries whose adaptive ``l`` hit the cap — bound caveat, see
      ``SearchResult``).
    * ``rejected`` — failed per-request validation (``error`` says why).
    * ``shed``     — refused by admission control (queue full).
    * ``deadline`` — dropped at dispatch, already past its deadline.
    * ``failed``   — every tier/retry exhausted (``error`` has the fault).
    """

    seq: int
    status: str
    ids: Optional[np.ndarray] = None
    dists: Optional[np.ndarray] = None
    rung: int = 0
    delta_bound: float = math.inf
    tier: str = ""
    saturated: bool = False
    deadline_missed: bool = False
    latency_s: float = 0.0
    error: Optional[str] = None
    # -- shard coverage accounting (1.0 / 0 on single-node serving) ----------
    coverage: float = 1.0               # live logical shards / S
    max_missed: int = 0                 # worst-case true neighbors lost

    @property
    def ok(self) -> bool:
        return self.status == "ok"


@dataclasses.dataclass
class _RRequest(_Request):
    deadline_t: float = math.inf        # wall-clock absolute deadline


class SearchFailure(RuntimeError):
    """Raised internally when a batch exhausts every tier and retry."""


class ResilientAnnServer(AnnServer):
    """``AnnServer`` wrapped in the containment layers (module docstring).

    ``drain()`` returns ``list[Response]`` in submission order — terminal
    responses (rejected / shed / deadline) included, so trace replays get
    one response per submitted request, crash-free by construction.
    """

    def __init__(self, index, params: SearchParams, *,
                 config: ResilienceConfig = ResilienceConfig(),
                 clock=time.monotonic, **kw):
        super().__init__(index, params, **kw)
        self.config = config
        graph = index.graph if isinstance(index, EMQGIndex) else index
        delta = config.delta if config.delta is not None \
            else float(getattr(graph, "delta", 0.0))
        self.ladder = DegradationLadder(params, delta, config.n_rungs)
        self.breaker = CircuitBreaker(
            default_tiers(self.engine, self.backend),
            threshold=config.breaker_threshold,
            cooldown_s=config.breaker_cooldown_s, clock=clock)
        self.rung = 0
        self._done: list[Response] = []
        self._last_tier: Optional[int] = None
        self._last_result = None            # full SearchResult of last batch
        self._last_coverage: float = 1.0
        self._last_max_missed: int = 0

    # -- request path -------------------------------------------------------
    def submit(self, query, arrival_t: Optional[float] = None,
               deadline_s: Optional[float] = None) -> Optional[Response]:
        """Queue a request.  Returns the terminal ``Response`` immediately if
        it was rejected or shed (also delivered again by ``drain()``), else
        ``None`` — the result arrives from ``drain()``."""
        wall = Timer.now()
        seq = self._seq
        self._seq += 1
        reason = validate_query(query, self.index.dim)
        if reason is not None:
            self.stats.n_rejected += 1
            if self.metrics is not None:
                self.metrics.counter("serve_responses_total",
                                     {"status": "rejected"}).inc()
            resp = Response(seq=seq, status="rejected", error=reason)
            self._done.append(resp)
            return resp
        if len(self._queue) >= self.config.max_queue:
            self.stats.n_shed += 1
            if self.metrics is not None:
                self.metrics.counter("serve_responses_total",
                                     {"status": "shed"}).inc()
            resp = Response(seq=seq, status="shed",
                            error=f"queue full ({self.config.max_queue})")
            self._done.append(resp)
            return resp
        deadline_s = deadline_s if deadline_s is not None \
            else self.config.deadline_s
        self._queue.append(_RRequest(
            arrival_t=arrival_t if arrival_t is not None else wall,
            wall_t=wall, query=np.asarray(query, np.float32), seq=seq,
            deadline_t=wall + deadline_s if deadline_s is not None
            else math.inf))
        return None

    # -- degradation ladder --------------------------------------------------
    def _adjust_rung(self, depth: int) -> None:
        old = self.rung
        if depth > self.config.degrade_depth:
            self.rung = min(self.rung + 1, len(self.ladder) - 1)
        elif depth < self.config.recover_depth:
            self.rung = max(self.rung - 1, 0)
        if self.metrics is not None and self.rung != old:
            direction = "down" if self.rung > old else "up"
            self.metrics.counter(
                "serve_degradation_transitions_total",
                {"direction": direction, "rung": str(self.rung)}).inc()
            self.metrics.event(
                "serve_degradation_transition",
                from_rung=old, rung=self.rung, direction=direction,
                reason=f"queue_depth={depth}",
                delta_bound=self.ladder.delta_bound(self.rung))
            self.metrics.gauge("serve_rung").set(self.rung)

    # -- failure containment around the hot path -----------------------------
    def _search_contained(self, qs: np.ndarray, params: SearchParams):
        """One batch through retry + breaker.  Returns (result, tier_name)
        with host-materialized arrays (deferred device errors surface here,
        inside the containment), or raises ``SearchFailure``."""
        cfg = self.config
        last_err: Optional[BaseException] = None
        # Budget enough attempts to walk the whole fallback chain even when
        # every upper tier must first fail its way to OPEN — a batch should
        # only fail once the *last* tier has genuinely been exhausted.
        attempts = cfg.max_retries + \
            cfg.breaker_threshold * (len(self.breaker.tiers) - 1) + 1
        for attempt in range(attempts):
            i, tier = self.breaker.current()
            if self._last_tier is not None and i != self._last_tier:
                self.stats.n_fallback += 1
                if self.metrics is not None:
                    prev = self.breaker.tiers[self._last_tier].name
                    self.metrics.counter(
                        "serve_breaker_transitions_total",
                        {"from": prev, "to": tier.name}).inc()
                    self.metrics.event("serve_breaker_transition",
                                       from_tier=prev, to_tier=tier.name,
                                       reason="tier_open"
                                       if i > self._last_tier else "recovery")
            self._last_tier = i
            try:
                tier_params = params if tier.beam_width is None else \
                    dataclasses.replace(params, beam_width=tier.beam_width)
                res = self._search(jnp.asarray(qs), params=tier_params,
                                   engine=tier.engine, backend=tier.backend)
                out = (np.asarray(res.ids), np.asarray(res.dists),
                       np.asarray(res.saturated))
                self.breaker.record_success(i)
                self._last_result = res     # device counters for _obs_batch
                return out, tier.name
            except Exception as e:
                last_err = e
                self.breaker.record_failure(i)
                if attempt < attempts - 1:
                    self.stats.n_retried += 1
                    if cfg.backoff_s > 0:
                        time.sleep(min(cfg.backoff_s * (2 ** attempt),
                                       cfg.backoff_cap_s))
        raise SearchFailure(f"{type(last_err).__name__}: {last_err}") \
            from last_err

    # -- serve loop ----------------------------------------------------------
    def drain(self) -> list[Response]:
        """Serve everything queued; one ``Response`` per submitted request,
        in submission order.  Never raises on search faults — worst case is
        ``status="failed"`` responses with the error attached."""
        out = self._done
        self._done = []
        tr = self.tracer
        while self._queue:
            self._adjust_rung(len(self._queue))
            take = self._queue[: self.max_batch]
            self._queue = self._queue[self.max_batch:]

            bspan = tr.start_span("serve.batch", rung=self.rung) \
                if tr else None
            fspan = tr.start_span("serve.batch_form", parent=bspan) \
                if tr else None
            now = Timer.now()
            live = []
            for req in take:
                if now > req.deadline_t:
                    self.stats.n_deadline_missed += 1
                    self._obs_response(req, now, now, "deadline",
                                       batch_span=bspan)
                    out.append(Response(
                        seq=req.seq, status="deadline",
                        latency_s=now - req.wall_t,
                        error="deadline exceeded before dispatch"))
                else:
                    live.append(req)
            if not live:
                if tr:
                    tr.end_span(fspan, size=0)
                    tr.end_span(bspan, size=0)
                continue

            qs = np.stack([r.query for r in live])
            bucket = self._bucket(len(live))
            pad = bucket - len(live)
            if pad:
                qs = np.concatenate([qs, np.repeat(qs[-1:], pad, axis=0)])
            rung = self.rung
            params = self.ladder.params(rung)
            bound = self.ladder.delta_bound(rung)
            if tr:
                tr.end_span(fspan, size=len(live), bucket=bucket)
            espan = None
            if tr:
                espan = tr.start_span("serve.device_execute", parent=bspan,
                                      rung=rung)
                tr.activate(espan)      # shard fan-out spans nest under it
            t0 = Timer.now()
            try:
                (ids, dists, sat), tier_name = \
                    self._search_contained(qs, params)
            except SearchFailure as e:
                t1 = Timer.now()
                if tr:
                    tr.deactivate(espan)
                    tr.end_span(espan, error=str(e))
                self._obs_batch(len(live), None, t1 - t0)
                for req in live:
                    self.stats.n_failed += 1
                    self._obs_response(req, t0, t1, "failed",
                                       batch_span=bspan)
                    out.append(Response(seq=req.seq, status="failed",
                                        rung=rung, latency_s=t1 - req.wall_t,
                                        error=str(e)))
                self.stats.n_batches += 1
                self.stats.total_search_s += t1 - t0
                if tr:
                    tr.end_span(bspan, size=len(live), status="failed")
                continue
            t1 = Timer.now()
            if tr:
                tr.deactivate(espan)
                tr.end_span(espan, tier=tier_name)
            self._obs_batch(len(live), self._last_result, t1 - t0)
            mspan = tr.start_span("serve.merge", parent=bspan) if tr else None
            for i, req in enumerate(live):
                lat = t1 - req.wall_t
                missed = t1 > req.deadline_t
                self.stats.n_requests += 1
                self.stats.total_latency_s += lat
                self.stats.max_latency_s = max(self.stats.max_latency_s, lat)
                if rung > 0:
                    self.stats.n_degraded += 1
                if missed:
                    self.stats.n_deadline_missed += 1
                self._obs_response(req, t0, t1, "ok", batch_span=bspan)
                out.append(Response(
                    seq=req.seq, status="ok", ids=ids[i], dists=dists[i],
                    rung=rung, delta_bound=bound, tier=tier_name,
                    saturated=bool(sat[i]), deadline_missed=missed,
                    latency_s=lat, coverage=self._last_coverage,
                    max_missed=self._last_max_missed))
            self.stats.n_batches += 1
            self.stats.total_search_s += t1 - t0
            if tr:
                tr.end_span(mspan)
                tr.end_span(bspan, size=len(live), tier=tier_name)
        out.sort(key=lambda r: r.seq)
        return out


# ---------------------------------------------------------------------------
# Sharded resilient serving (distributed fault tolerance).
# ---------------------------------------------------------------------------


class ShardedResilientAnnServer(ResilientAnnServer):
    """The resilient server fronting a ``ShardedIndex``.

    The search seam routes to a registry-masked ``shard_map`` search
    (``core.distributed.FaultTolerantShardedSearch``); the breaker chain is
    the two merge strategies — a merge-time collective fault (the ring's
    ``ppermute`` step dying with a shard) opens the primary merge tier and
    falls back to the other, same-exactness merge.  Shard death is NOT a
    breaker event: the registry masks the dead shard out and serving
    continues at reduced coverage, reported per response (``coverage``,
    ``max_missed``) — availability degrades *explicitly*, never silently.

    ``kill_shard`` / ``revive_shard`` are the operator surface (a health
    checker would drive them); with ``n_replicas > 1`` a killed primary
    fails over to its replica before coverage degrades at all.

    **Self-healing** (``auto_repair=``): with a durable ``vector_store``
    (a ``core.repair.ShardVectorStore`` or its directory path), a
    ``RepairController`` is swept once per dispatch — after the health
    check kills stale replicas, before the batch routes — so a dead slot
    is rebuilt from source, verified, atomically installed, and
    ``mark_live``-d without any operator call.  Pass ``True`` for the
    default ``RepairConfig`` or a ``RepairConfig`` to tune budget/backoff.
    """

    def __init__(self, sidx, params: SearchParams, mesh, *,
                 shard_axes=("data",), query_axis=None,
                 merge: str = "all_gather", quantized: bool = False,
                 n_replicas: int = 1,
                 config: ResilienceConfig = ResilienceConfig(),
                 clock=time.monotonic, health_deadline_s=None,
                 auto_repair=None, vector_store=None,
                 repair_fault_hook=None, **kw):
        from repro.core.distributed import (DeadlineHealthChecker,
                                            FaultTolerantShardedSearch,
                                            ShardHealthRegistry)
        super().__init__(sidx, params, config=config, clock=clock,
                         engine="beam", backend="auto", **kw)
        self.quantized = quantized          # ShardedIndex defeats isinstance
        self.registry = ShardHealthRegistry(sidx.n_shards // n_replicas,
                                            n_replicas, clock=clock)
        # deadline-based health checking: replicas heartbeat via
        # ``heartbeat()``; a stale one is auto-mark_dead-ed before the next
        # batch dispatches (None → explicit kill_shard/revive_shard only)
        self.health_checker = None if health_deadline_s is None else \
            DeadlineHealthChecker(self.registry, health_deadline_s,
                                  metrics=self.metrics)
        merges = [merge]
        other = "ring" if merge == "all_gather" else "all_gather"
        if len(shard_axes) == 1 and other not in merges:
            merges.append(other)
        self._ft = {
            m: FaultTolerantShardedSearch(
                sidx, mesh, shard_axes=shard_axes, query_axis=query_axis,
                merge=m, quantized=quantized, n_replicas=n_replicas,
                registry=self.registry)
            for m in merges
        }
        self.breaker = CircuitBreaker(
            [("sharded", m) for m in merges],
            threshold=config.breaker_threshold,
            cooldown_s=config.breaker_cooldown_s, clock=clock)
        self.repair = None
        if auto_repair:
            from repro.core.repair import (RepairConfig, RepairController,
                                           ShardVectorStore)
            if vector_store is None:
                raise ValueError("auto_repair requires vector_store (a "
                                 "ShardVectorStore or its directory path)")
            if isinstance(vector_store, str):
                vector_store = ShardVectorStore(vector_store)
            self.repair = RepairController(
                vector_store, self.registry,
                get_sidx=lambda: self.index,
                set_sidx=self._install_sidx,
                config=auto_repair if isinstance(auto_repair, RepairConfig)
                else None,
                clock=clock, metrics=self.metrics,
                fault_hook=repair_fault_hook)

    def _install_sidx(self, sidx) -> None:
        """Atomic index swap: the new pytree replaces the old for every
        searcher at once (the next batch sees one consistent index)."""
        self.index = sidx
        for ft in self._ft.values():
            ft.sidx = sidx

    # -- operator surface ----------------------------------------------------
    def kill_shard(self, shard: int, replica: int = 0) -> None:
        self.registry.mark_dead(shard, replica)

    def revive_shard(self, shard: int, replica: int = 0) -> None:
        self.registry.mark_live(shard, replica)

    def heartbeat(self, shard: int, replica: int = 0) -> None:
        """Liveness signal from a shard's host (the transport layer would
        call this); consumed by the deadline health checker."""
        self.registry.heartbeat(shard, replica)

    @property
    def coverage(self) -> float:
        return self.registry.coverage()

    # -- search seam ---------------------------------------------------------
    def _search(self, queries, params: Optional[SearchParams] = None,
                engine: Optional[str] = None,
                backend: Optional[str] = None):
        params = params if params is not None else self.params
        if engine is not None and engine != "sharded":
            return super()._search(queries, params=params, engine=engine,
                                   backend=backend)
        merge = backend if backend in self._ft else next(iter(self._ft))
        if self.health_checker is not None:
            self.health_checker.check()     # stale heartbeats → mark_dead
        if self.repair is not None:
            self.repair.sweep()             # dead slots → rebuild + install
        tr = self.tracer
        if tr is not None:
            # fan-out spans: one child per logical shard under a fanout
            # parent (itself a child of the batch's device_execute span via
            # the tracer stack when drain uses it, else standalone).  The
            # shard_map collective is lock-step, so every shard child spans
            # the same interval; the payload is the liveness attribution.
            fanout = tr.start_span("serve.shard_fanout", merge=merge)
            shard_spans = [
                tr.start_span("shard", parent=fanout, shard=s,
                              live=bool(self.registry._live[s].any()))
                for s in range(self.registry.n_shards)]
        r = self._ft[merge](queries, params)
        if tr is not None:
            for ss in shard_spans:
                tr.end_span(ss)
            tr.end_span(fanout, coverage=r.coverage,
                        max_missed=r.max_missed)
        if self.metrics is not None:
            self.registry.publish(self.metrics)
        self._last_coverage = r.coverage
        self._last_max_missed = r.max_missed
        B = r.ids.shape[0]
        zeros = jnp.zeros((B,), jnp.int32)
        return SearchResult(ids=r.ids, dists=r.dists, n_dist_comps=zeros,
                            n_approx_comps=zeros, n_hops=zeros,
                            final_l=zeros, saturated=jnp.zeros((B,), bool),
                            n_encounters=zeros)
