"""Batched ANN request serving on a δ-EMG / δ-EMQG index.

Request-level batching is how a lock-step accelerator search serves an
online stream: requests accumulate until ``max_batch`` or ``max_wait_s``
elapses, the batch is padded to a fixed bucket size (one trace per bucket),
and per-request results are fanned back out.  Straggler mitigation falls out
of the lock-step formulation — a hard query costs masked iterations instead
of blocking a core.

The server runs the batch-level beam engine: ``params.beam_width`` widens
the per-hop frontier (fewer, fatter lock-step iterations per batch — the
QPS/latency knob), and ``backend`` selects the fused gather+L2
implementation for the distance hot path ("auto" picks the tiled Pallas
kernel on TPU, plain XLA elsewhere).  ``engine="legacy"`` keeps the seed
per-query engine reachable for A/B traffic splits while the parity suite
soaks.

Single-process implementation (threads would add nothing in a test
container); the ``submit_many`` / ``drain`` pair models the arrival loop so
benchmarks can replay request traces with arrival timestamps.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import (
    EMQGIndex,
    GraphIndex,
    SearchParams,
    legacy_probing_search,
    legacy_search,
    probing_search,
    search,
)


@dataclasses.dataclass
class ServeStats:
    n_requests: int = 0
    n_batches: int = 0
    total_latency_s: float = 0.0
    max_latency_s: float = 0.0
    total_search_s: float = 0.0

    @property
    def mean_latency_s(self) -> float:
        return self.total_latency_s / max(self.n_requests, 1)

    @property
    def qps(self) -> float:
        return self.n_requests / max(self.total_search_s, 1e-9)


class AnnServer:
    def __init__(self, index: GraphIndex | EMQGIndex, params: SearchParams,
                 max_batch: int = 64, buckets: tuple[int, ...] = (8, 32, 64),
                 engine: str = "beam", backend: str = "auto"):
        if engine not in ("beam", "legacy"):
            raise ValueError(f"unknown engine: {engine!r}")
        self.index = index
        self.params = params
        self.max_batch = max_batch
        self.buckets = tuple(sorted(set(b for b in buckets if b <= max_batch))) \
            or (max_batch,)
        self.quantized = isinstance(index, EMQGIndex)
        self.engine = engine
        self.backend = backend
        self._queue: list[tuple[float, np.ndarray]] = []
        self.stats = ServeStats()

    def _search(self, queries: jnp.ndarray):
        if self.quantized:
            if self.engine == "beam":
                return probing_search(self.index, queries, self.params,
                                      backend=self.backend)
            return legacy_probing_search(self.index, queries, self.params)
        if self.engine == "beam":
            return search(self.index, queries, self.params,
                          backend=self.backend)
        return legacy_search(self.index, queries, self.params)

    # -- request path -------------------------------------------------------
    def submit(self, query: np.ndarray, arrival_t: Optional[float] = None):
        self._queue.append((arrival_t if arrival_t is not None else time.time(),
                            np.asarray(query, np.float32)))

    def submit_many(self, queries: np.ndarray, arrival_ts=None):
        for i, q in enumerate(queries):
            self.submit(q, None if arrival_ts is None else float(arrival_ts[i]))

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def drain(self) -> list[tuple[np.ndarray, np.ndarray]]:
        """Serve everything queued; returns [(ids, dists)] per request in
        submission order."""
        out = []
        while self._queue:
            take = self._queue[: self.max_batch]
            self._queue = self._queue[self.max_batch:]
            ts = np.array([t for t, _ in take])
            qs = np.stack([q for _, q in take])
            bucket = self._bucket(len(take))
            pad = bucket - len(take)
            if pad:
                qs = np.concatenate([qs, np.repeat(qs[-1:], pad, axis=0)])
            t0 = time.time()
            res = self._search(jnp.asarray(qs))
            ids = np.asarray(res.ids)
            dists = np.asarray(res.dists)
            t1 = time.time()
            for i in range(len(take)):
                out.append((ids[i], dists[i]))
                lat = t1 - ts[i]
                self.stats.n_requests += 1
                self.stats.total_latency_s += lat
                self.stats.max_latency_s = max(self.stats.max_latency_s, lat)
            self.stats.n_batches += 1
            self.stats.total_search_s += t1 - t0
        return out
