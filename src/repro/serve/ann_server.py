"""Batched ANN request serving on a δ-EMG / δ-EMQG index.

Request-level batching is how a lock-step accelerator search serves an
online stream: requests accumulate until ``max_batch`` or ``max_wait_s``
elapses, the batch is padded to a fixed bucket size (one trace per bucket),
and per-request results are fanned back out.  Straggler mitigation falls out
of the lock-step formulation — a hard query costs masked iterations instead
of blocking a core.

The server runs the batch-level beam engine — the only engine: ``params.
beam_width`` widens the per-hop frontier (fewer, fatter lock-step iterations
per batch — the QPS/latency knob), and ``backend`` selects the fused
gather+L2 implementation for the distance hot path ("auto" picks the tiled
Pallas kernel on TPU, plain XLA elsewhere).  The resilience layer
(``resilience.py``) wraps this server with admission control, deadlines, and
an error-bounded degradation ladder whose circuit breaker bottoms out at
``(beam, jnp, beam_width=1)`` — greedy best-first on the production engine.

Clocks: every request records two timestamps — ``arrival_t``, the *logical*
arrival time (caller-supplied when replaying a trace, else the submit
instant), and ``wall_t``, the **monotonic** submit time
(``time.perf_counter`` via ``obs.Timer``).  All latency accounting is
two-point monotonic arithmetic (submit → completion); logical arrivals only
order the replay.  The stepping wall clock is banned from this package (CI
grep-lint rejects any ``time.<wall-clock>()`` call): it steps under NTP, and
the seed's wall-clock subtraction could report negative latencies after a
slew.

Observability: pass ``metrics=`` (an ``obs.MetricsRegistry``) and/or
``tracer=`` (an ``obs.Tracer``) to get the standard serve taxonomy —
request-latency / queue-wait / batch-execute histograms, per-status response
counters, batch-aggregated device counters (``n_dist_comps``/``n_hops``/…,
the Exp-5 metrics at serve time) — and per-request spans (``serve.request``
with a ``serve.queue_wait`` child) linked to per-batch spans
(``serve.batch`` → ``serve.batch_form`` / ``serve.device_execute`` /
``serve.merge``).  Both default to ``None`` = zero overhead, and enabling
them cannot change results (pinned bit-identical in ``tests/test_obs.py``).

Single-process implementation (threads would add nothing in a test
container); the ``submit_many`` / ``drain`` pair models the arrival loop so
benchmarks can replay request traces with arrival timestamps.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import (
    EMQGIndex,
    GraphIndex,
    SearchParams,
    probing_search,
    search,
)
from repro.obs import (
    DEFAULT_WORK_BUCKETS,
    MetricsRegistry,
    Timer,
    Tracer,
    record_search_result,
)


@dataclasses.dataclass
class ServeStats:
    """Serve-loop counters.  The resilience counters (``n_shed`` onward) stay
    zero under the plain ``AnnServer``; ``ResilientAnnServer`` drives them."""

    n_requests: int = 0
    n_batches: int = 0
    total_latency_s: float = 0.0
    max_latency_s: float = 0.0
    total_search_s: float = 0.0
    # -- resilience counters -------------------------------------------------
    n_rejected: int = 0          # failed per-request validation (shape/NaN/…)
    n_shed: int = 0              # refused by admission control (queue full)
    n_degraded: int = 0          # served at a ladder rung below full quality
    n_retried: int = 0           # search attempts retried after a fault
    n_fallback: int = 0          # circuit-breaker tier switches
    n_deadline_missed: int = 0   # completed after their deadline
    n_failed: int = 0            # exhausted every tier/retry; error response

    @property
    def mean_latency_s(self) -> float:
        return self.total_latency_s / max(self.n_requests, 1)

    @property
    def qps(self) -> float:
        return self.n_requests / max(self.total_search_s, 1e-9)


@dataclasses.dataclass
class _Request:
    """A queued request: logical arrival (trace clock) + monotonic submit."""

    arrival_t: float
    wall_t: float
    query: np.ndarray
    seq: int


class AnnServer:
    def __init__(self, index: GraphIndex | EMQGIndex, params: SearchParams,
                 max_batch: int = 64, buckets: tuple[int, ...] = (8, 32, 64),
                 engine: str = "beam", backend: str = "auto",
                 metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        if engine != "beam":
            raise ValueError(f"unknown engine: {engine!r}")
        self.index = index
        self.params = params
        self.max_batch = max_batch
        self.buckets = tuple(sorted(set(b for b in buckets if b <= max_batch))) \
            or (max_batch,)
        self.quantized = isinstance(index, EMQGIndex)
        self.engine = engine
        self.backend = backend
        self.metrics = metrics
        self.tracer = tracer
        self._queue: list[_Request] = []
        self._seq = 0
        self.stats = ServeStats()

    def _search(self, queries: jnp.ndarray,
                params: Optional[SearchParams] = None,
                engine: Optional[str] = None,
                backend: Optional[str] = None):
        """Run one batch through the beam engine.  The overrides are the seam
        the resilience layer steers (ladder params, breaker tier) and the
        fault harness wraps; ``engine`` stays a parameter so breaker tiers
        remain addressable (the sharded subclass adds its own tiers)."""
        params = params if params is not None else self.params
        engine = engine if engine is not None else self.engine
        backend = backend if backend is not None else self.backend
        if engine != "beam":
            raise ValueError(f"unknown engine: {engine!r}")
        if self.quantized:
            return probing_search(self.index, queries, params, backend=backend)
        return search(self.index, queries, params, backend=backend)

    # -- observability seams -------------------------------------------------
    def _obs_batch(self, n_live: int, res, exec_s: float) -> None:
        """Batch-level metrics: execute-time histogram, batch size, and the
        device-side work counters aggregated host-side (Exp-5 at serve
        time).  ``n_live`` excludes pad rows from the aggregation."""
        if self.metrics is None:
            return
        self.metrics.histogram("serve_batch_execute_seconds").observe(exec_s)
        self.metrics.histogram("serve_batch_size",
                               buckets=DEFAULT_WORK_BUCKETS).observe(n_live)
        if res is not None:
            record_search_result(self.metrics, res, n_live=n_live)

    def _obs_response(self, req: _Request, dispatch_t: float, done_t: float,
                      status: str, batch_span=None) -> None:
        """Per-request metrics + retroactive request/queue-wait spans."""
        if self.metrics is not None:
            self.metrics.counter("serve_responses_total",
                                 {"status": status}).inc()
            if status in ("ok", "failed"):
                self.metrics.histogram(
                    "serve_request_latency_seconds").observe(
                        done_t - req.wall_t)
                self.metrics.histogram("serve_queue_wait_seconds").observe(
                    max(dispatch_t - req.wall_t, 0.0))
        if self.tracer is not None:
            rspan = self.tracer.start_span(
                "serve.request", seq=req.seq, status=status,
                batch=None if batch_span is None else batch_span.span_id)
            rspan.start = req.wall_t
            qspan = self.tracer.start_span("serve.queue_wait", parent=rspan)
            qspan.start = req.wall_t
            self.tracer.end_span(qspan, end=dispatch_t)
            self.tracer.end_span(rspan, end=done_t)

    # -- request path -------------------------------------------------------
    def submit(self, query: np.ndarray, arrival_t: Optional[float] = None):
        wall = Timer.now()
        self._queue.append(_Request(
            arrival_t=arrival_t if arrival_t is not None else wall,
            wall_t=wall, query=np.asarray(query, np.float32), seq=self._seq))
        self._seq += 1

    def submit_many(self, queries: np.ndarray, arrival_ts=None):
        for i, q in enumerate(queries):
            self.submit(q, None if arrival_ts is None else float(arrival_ts[i]))

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        # n exceeds every bucket (max_batch > largest bucket): serve unpadded
        # rather than computing a negative pad.
        return n

    def drain(self) -> list[tuple[np.ndarray, np.ndarray]]:
        """Serve everything queued; returns [(ids, dists)] per request in
        submission order."""
        out = []
        tr = self.tracer
        while self._queue:
            take = self._queue[: self.max_batch]
            self._queue = self._queue[self.max_batch:]
            bspan = tr.start_span("serve.batch") if tr else None
            fspan = tr.start_span("serve.batch_form", parent=bspan) \
                if tr else None
            qs = np.stack([r.query for r in take])
            bucket = self._bucket(len(take))
            pad = bucket - len(take)
            if pad:
                qs = np.concatenate([qs, np.repeat(qs[-1:], pad, axis=0)])
            if tr:
                tr.end_span(fspan, size=len(take), bucket=bucket)
            espan = tr.start_span("serve.device_execute", parent=bspan,
                                  backend=self.backend) if tr else None
            t0 = Timer.now()
            res = self._search(jnp.asarray(qs))
            ids = np.asarray(res.ids)
            dists = np.asarray(res.dists)
            t1 = Timer.now()
            if tr:
                tr.end_span(espan)
            self._obs_batch(len(take), res, t1 - t0)
            mspan = tr.start_span("serve.merge", parent=bspan) if tr else None
            for i, req in enumerate(take):
                out.append((ids[i], dists[i]))
                lat = t1 - req.wall_t
                self.stats.n_requests += 1
                self.stats.total_latency_s += lat
                self.stats.max_latency_s = max(self.stats.max_latency_s, lat)
                self._obs_response(req, t0, t1, "ok", batch_span=bspan)
            if tr:
                tr.end_span(mspan)
                tr.end_span(bspan, size=len(take))
            self.stats.n_batches += 1
            self.stats.total_search_s += t1 - t0
        return out
