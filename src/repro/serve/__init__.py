from .ann_server import AnnServer, ServeStats  # noqa: F401
from .lm_server import generate  # noqa: F401
