from .ann_server import AnnServer, ServeStats  # noqa: F401
from .lm_server import generate  # noqa: F401
from .resilience import (  # noqa: F401
    CircuitBreaker,
    DegradationLadder,
    ResilienceConfig,
    ResilientAnnServer,
    Response,
    ShardedResilientAnnServer,
    validate_query,
)
