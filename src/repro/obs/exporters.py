"""Exporters: Prometheus text exposition, JSON snapshot, stderr summary.

All three render the same :class:`~repro.obs.metrics.MetricsRegistry`; none
of them mutate it, so exporting can never perturb serving (the
metrics-on/off conformance test in ``tests/test_obs.py`` pins that).

* ``to_prometheus`` — text exposition format (``# TYPE`` headers,
  cumulative ``_bucket{le=...}`` lines, ``_sum``/``_count``, plus
  non-cumulative ``{quantile=...}`` convenience lines so p50/p95/p99 are
  scrapeable without a ``histogram_quantile`` recording rule).
* ``to_json`` / ``snapshot`` — a round-trippable dict (counters, gauges,
  histograms with percentiles, recent structured events, optional spans).
* ``summary_line`` / ``PeriodicSummary`` — the one-line operator heartbeat
  ``launch/serve.py --metrics-every`` emits to stderr between batches.
"""

from __future__ import annotations

import json
import math
import sys
import time
from typing import Optional

from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .tracing import Tracer

_QUANTILES = (0.5, 0.95, 0.99)


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    return repr(float(v))


def _label_str(label_key: tuple, extra: Optional[list] = None) -> str:
    pairs = list(label_key) + (extra or [])
    if not pairs:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in pairs) + "}"


def to_prometheus(registry: MetricsRegistry) -> str:
    lines: list[str] = []
    for name, kind, help, children in registry.families():
        if help:
            lines.append(f"# HELP {name} {help}")
        lines.append(f"# TYPE {name} {kind}")
        for label_key, child in children:
            if kind == "counter":
                lines.append(f"{name}{_label_str(label_key)} "
                             f"{_fmt(child.value)}")
            elif kind == "gauge":
                lines.append(f"{name}{_label_str(label_key)} "
                             f"{_fmt(child.value)}")
            else:
                for edge, cum in child.cumulative():
                    lines.append(
                        f"{name}_bucket"
                        f"{_label_str(label_key, [('le', _fmt(edge))])} "
                        f"{cum}")
                lines.append(f"{name}_sum{_label_str(label_key)} "
                             f"{_fmt(child.sum)}")
                lines.append(f"{name}_count{_label_str(label_key)} "
                             f"{child.count}")
                for q in _QUANTILES:
                    lines.append(
                        f"{name}{_label_str(label_key, [('quantile', str(q))])}"
                        f" {_fmt(child.quantile(q))}")
    return "\n".join(lines) + "\n"


def snapshot(registry: MetricsRegistry,
             tracer: Optional[Tracer] = None) -> dict:
    """Registry (and optionally trace ring) as a plain round-trippable dict."""
    out: dict = {"counters": {}, "gauges": {}, "histograms": {}, "events": []}
    for name, kind, _help, children in registry.families():
        for label_key, child in children:
            key = name + _label_str(label_key)
            if isinstance(child, Counter):
                out["counters"][key] = child.value
            elif isinstance(child, Gauge):
                out["gauges"][key] = child.value
            elif isinstance(child, Histogram):
                out["histograms"][key] = {
                    "count": child.count,
                    "sum": child.sum,
                    "mean": child.mean,
                    "min": child.min if child.count else None,
                    "max": child.max if child.count else None,
                    "buckets": [[b, c] for b, c in
                                zip(child.bounds, child.counts)],
                    "overflow": child.overflow,
                    **child.percentiles(),
                }
    out["events"] = [dict(e) for e in registry.events]
    if tracer is not None:
        out["spans"] = tracer.to_dicts()
    return out


def to_json(registry: MetricsRegistry, tracer: Optional[Tracer] = None,
            indent: Optional[int] = None) -> str:
    return json.dumps(snapshot(registry, tracer), indent=indent,
                      sort_keys=True)


def summary_line(registry: MetricsRegistry) -> str:
    """One operator-readable line: request volume, latency quantiles, rung,
    coverage — whatever of the standard taxonomy is present."""
    parts = []
    snap = snapshot(registry)
    lat = snap["histograms"].get("serve_request_latency_seconds")
    if lat:
        parts.append(f"req={lat['count']} "
                     f"p50={lat['p50'] * 1e3:.1f}ms "
                     f"p95={lat['p95'] * 1e3:.1f}ms "
                     f"p99={lat['p99'] * 1e3:.1f}ms")
    qw = snap["histograms"].get("serve_queue_wait_seconds")
    if qw and qw["count"]:
        parts.append(f"qwait_p95={qw['p95'] * 1e3:.1f}ms")
    for g in ("serve_rung", "shard_coverage"):
        if g in snap["gauges"]:
            parts.append(f"{g.split('_', 1)[1]}={snap['gauges'][g]:g}")
    for status in ("failed", "shed"):
        v = snap["counters"].get('serve_responses_total{status="%s"}' % status)
        if v:
            parts.append(f"{status}={v:g}")
    ndist = snap["counters"].get("search_dist_comps_total")
    if ndist is not None:
        parts.append(f"ndist={ndist:g}")
    return "[obs] " + (" ".join(parts) if parts else "no samples")


class PeriodicSummary:
    """Emit ``summary_line`` to ``stream`` at most every ``every_s`` seconds.

    Call :meth:`tick` from the serve loop between batches; it is a no-op
    until the interval has elapsed (monotonic clock).  ``every_s <= 0``
    disables it entirely.
    """

    def __init__(self, registry: MetricsRegistry, every_s: float,
                 stream=None, clock=time.perf_counter):
        self.registry = registry
        self.every_s = float(every_s)
        self.stream = stream if stream is not None else sys.stderr
        self.clock = clock
        self._last = clock()

    def tick(self, force: bool = False) -> Optional[str]:
        if self.every_s <= 0 and not force:
            return None
        now = self.clock()
        if force or now - self._last >= self.every_s:
            self._last = now
            line = summary_line(self.registry)
            print(line, file=self.stream, flush=True)
            return line
        return None
