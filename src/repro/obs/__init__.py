"""Unified observability layer: metrics registry, span tracing, exporters.

One substrate for every layer of the system — the servers
(``repro.serve``), the builder (``core.build_approx``), the WAL
(``core.updates``), shard health (``core.distributed``) and the benchmark
harness all observe into the same registry types, so "what does a request
cost" has a single answer with a single bucket math.

Metric taxonomy (names are stable API — the README documents them):

======================================  =========  ==============================
name                                    kind       meaning
======================================  =========  ==============================
serve_request_latency_seconds           histogram  submit → response, monotonic
serve_queue_wait_seconds                histogram  submit → batch dispatch
serve_batch_execute_seconds             histogram  device search per batch
serve_batch_size                        histogram  requests per dispatched batch
serve_responses_total{status}           counter    ok/rejected/shed/deadline/failed
serve_degradation_transitions_total
  {direction,rung}                      counter    ladder steps (event: bound)
serve_breaker_transitions_total
  {from,to}                             counter    circuit-breaker tier moves
serve_rung                              gauge      current ladder rung
search_dist_comps_total                 counter    exact distance evals (Exp-5)
search_approx_comps_total               counter    quantized evals (δ-EMQG)
search_hops_total                       counter    expansions
search_encounters_total                 counter    pre-dedup candidate encounters
search_saturated_total                  counter    queries whose adaptive l capped
search_final_l                          histogram  per-query final beam length
shard_live{shard}                       gauge      1 = some replica live
shard_coverage                          gauge      live logical shards / S
shard_failover                          gauge      shards served by non-primary
shard_heartbeat_age_seconds{shard}      gauge      min age over live replicas
shard_replica_heartbeat_age_seconds
  {shard,replica}                       gauge      raw per-slot heartbeat age
shard_marked_dead_total                 counter    health-checker kills
repair_started_total                    counter    repair attempts begun
repair_succeeded_total                  counter    verified installs completed
repair_failed_total                     counter    contained repair failures
shard_under_repair{shard}               gauge      1 from first attempt→success
repair_duration_seconds                 histogram  successful repair wall time
wal_append_seconds                      histogram  journal record commit
wal_fsync_seconds                       histogram  fsync inside atomic writes
wal_records_total{op}                   counter    committed journal records
checkpoint_save_seconds                 histogram  full snapshot commit
checkpoint_restore_seconds              histogram  recover() restore+replay
build_phase_seconds{phase}              histogram  builder phase wall time
build_nodes_total                       counter    nodes processed by the builder
======================================  =========  ==============================

Span taxonomy: ``serve.request`` (child ``serve.queue_wait``) per request;
``serve.batch`` per dispatched batch with children ``serve.batch_form``,
``serve.device_execute`` (children ``shard{shard,live}`` under sharded
fan-out) and ``serve.merge``.

Everything here is stdlib-only and observation-only: enabling metrics can
not change search results (pinned bit-identical by ``tests/test_obs.py``).
"""

from .exporters import (  # noqa: F401
    PeriodicSummary,
    snapshot,
    summary_line,
    to_json,
    to_prometheus,
)
from .metrics import (  # noqa: F401
    DEFAULT_LATENCY_BUCKETS_S,
    DEFAULT_WORK_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Timer,
)
from .tracing import Span, Tracer  # noqa: F401


def declare_serve_metrics(registry: MetricsRegistry,
                          n_shards: int = 1) -> MetricsRegistry:
    """Pre-register the full serve taxonomy so exports have a stable schema
    from the first scrape (families exist with zero samples before the
    first request arrives — standard exporter practice)."""
    registry.histogram("serve_request_latency_seconds",
                       help="submit-to-response latency (monotonic clock)")
    registry.histogram("serve_queue_wait_seconds",
                       help="submit-to-dispatch queue wait")
    registry.histogram("serve_batch_execute_seconds",
                       help="device search time per batch")
    registry.histogram("serve_batch_size", buckets=DEFAULT_WORK_BUCKETS,
                       help="requests per dispatched batch")
    for status in ("ok", "rejected", "shed", "deadline", "failed"):
        registry.counter("serve_responses_total", {"status": status},
                         help="responses by terminal status")
    registry.counter("serve_degradation_transitions_total",
                     {"direction": "down", "rung": "1"},
                     help="degradation-ladder transitions")
    registry.gauge("serve_rung", help="current degradation-ladder rung")
    registry.counter("search_dist_comps_total",
                     help="exact distance evaluations (Exp-5 metric)")
    registry.counter("search_approx_comps_total",
                     help="quantized distance evaluations")
    registry.counter("search_hops_total", help="search expansions")
    registry.counter("search_encounters_total",
                     help="pre-dedup candidate encounters")
    registry.counter("search_saturated_total",
                     help="queries whose adaptive l hit the cap")
    registry.histogram("search_final_l", buckets=DEFAULT_WORK_BUCKETS,
                       help="per-query final beam length")
    registry.gauge("shard_coverage",
                   help="live logical shards / total").set(1.0)
    registry.gauge("shard_failover",
                   help="shards served by a non-primary replica")
    for s in range(n_shards):
        registry.gauge("shard_live", {"shard": s},
                       help="1 if some replica of the shard is live").set(1.0)
    registry.counter("shard_marked_dead_total",
                     help="shards auto-killed by the health checker")
    registry.counter("repair_started_total",
                     help="shard repair attempts begun")
    registry.counter("repair_succeeded_total",
                     help="shard repairs verified and installed")
    registry.counter("repair_failed_total",
                     help="shard repair attempts that failed (will retry)")
    registry.histogram("repair_duration_seconds",
                       help="wall time of successful shard repairs")
    registry.histogram("wal_append_seconds",
                       help="WAL record commit (payload+manifest)")
    registry.histogram("wal_fsync_seconds",
                       help="fsync inside atomic WAL/meta writes")
    registry.histogram("checkpoint_save_seconds",
                       help="full snapshot commit")
    registry.histogram("checkpoint_restore_seconds",
                       help="recover(): restore + WAL replay")
    return registry


def record_search_result(registry: MetricsRegistry, res,
                         n_live: int = None) -> None:
    """Aggregate one batch's device-side ``SearchResult`` counters into
    host-side metrics.  ``n_live`` restricts the aggregation to the first
    ``n_live`` rows (padded rows repeat the last real query — counting them
    would double-bill the pad).  Read-only on ``res``.
    """
    import numpy as np  # deferred: keep `repro.obs` importable stdlib-only

    def rows(x):
        a = np.asarray(x)
        return a[:n_live] if n_live is not None else a

    registry.counter("search_dist_comps_total").inc(
        float(rows(res.n_dist_comps).sum()))
    registry.counter("search_hops_total").inc(float(rows(res.n_hops).sum()))
    if getattr(res, "n_approx_comps", None) is not None:
        registry.counter("search_approx_comps_total").inc(
            float(rows(res.n_approx_comps).sum()))
    if getattr(res, "n_encounters", None) is not None:
        registry.counter("search_encounters_total").inc(
            float(rows(res.n_encounters).sum()))
    registry.counter("search_saturated_total").inc(
        float(rows(res.saturated).sum()))
    fl = registry.histogram("search_final_l", buckets=DEFAULT_WORK_BUCKETS)
    for v in rows(res.final_l).tolist():
        fl.observe(float(v))
