"""Lightweight span tracing for the serve path.

A :class:`Span` is one timed unit of work on the request path — the whole
request, its queue wait, the batch's device execute, a shard's slice of a
fan-out — with a parent link so a request's cost decomposes hierarchically:

    serve.request (seq=17)
      └─ serve.queue_wait
    serve.batch (size=32)
      ├─ serve.batch_form
      ├─ serve.device_execute
      │    ├─ shard (shard=0, live=1)
      │    ├─ shard (1, live=0)   ← masked out by the health registry
      │    └─ ...
      └─ serve.merge

Spans use the monotonic clock (``time.perf_counter``), sequential integer
ids (deterministic — no RNG on the serve path), and land in a bounded ring
once finished.  The tracer is single-threaded by design, matching the
serve loop; the *current span* is an explicit stack, so ``with
tracer.span(...)`` nests automatically and ``start_span(parent=...)``
handles the cross-batch case where a child (batch) has many logical
parents (the requests in it) — there, requests carry a ``link`` attribute
listing the batch span instead, see ``ann_server.drain``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional


@dataclasses.dataclass
class Span:
    name: str
    span_id: int
    parent_id: Optional[int]
    start: float                       # perf_counter seconds
    end: Optional[float] = None
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return (self.end if self.end is not None
                else time.perf_counter()) - self.start

    @property
    def finished(self) -> bool:
        return self.end is not None

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def to_dict(self) -> dict:
        return {"name": self.name, "span_id": self.span_id,
                "parent_id": self.parent_id, "start": self.start,
                "end": self.end, "duration_s": self.duration_s,
                "attrs": dict(self.attrs)}


class Tracer:
    """Span factory + bounded ring of finished spans."""

    def __init__(self, max_spans: int = 4096):
        self.finished: deque[Span] = deque(maxlen=max_spans)
        self._stack: list[Span] = []
        self._next_id = 1
        self.n_started = 0

    # -- explicit API (non-lexical span lifetimes) ---------------------------
    def start_span(self, name: str, parent: Optional[Span] = None,
                   **attrs) -> Span:
        """Open a span.  ``parent`` wins over the implicit stack; pass
        ``parent=None`` explicitly via ``root=True`` semantics by not being
        inside a ``with tracer.span(...)`` block."""
        pid = parent.span_id if parent is not None else (
            self._stack[-1].span_id if self._stack else None)
        s = Span(name=name, span_id=self._next_id, parent_id=pid,
                 start=time.perf_counter(), attrs=dict(attrs))
        self._next_id += 1
        self.n_started += 1
        return s

    def end_span(self, span: Span, end: Optional[float] = None,
                 **attrs) -> Span:
        """Close a span.  ``end`` (a ``perf_counter`` timestamp) supports
        retroactive spans — e.g. a request span whose queue wait is only
        known at dispatch time."""
        if span.end is None:
            span.end = end if end is not None else time.perf_counter()
            span.attrs.update(attrs)
            self.finished.append(span)
        return span

    def activate(self, span: Span) -> Span:
        """Make ``span`` the implicit parent for spans started while it is
        active (non-lexical counterpart of ``with tracer.span(...)`` — used
        where try/except control flow crosses the span boundary)."""
        self._stack.append(span)
        return span

    def deactivate(self, span: Span) -> None:
        if self._stack and self._stack[-1] is span:
            self._stack.pop()

    # -- lexical API ---------------------------------------------------------
    def span(self, name: str, parent: Optional[Span] = None, **attrs):
        return _SpanCtx(self, name, parent, attrs)

    # -- queries (tests, exporters) ------------------------------------------
    def by_name(self, name: str) -> list[Span]:
        return [s for s in self.finished if s.name == name]

    def children_of(self, span: Span) -> list[Span]:
        return [s for s in self.finished if s.parent_id == span.span_id]

    def to_dicts(self) -> list[dict]:
        return [s.to_dict() for s in self.finished]


class _SpanCtx:
    __slots__ = ("tracer", "name", "parent", "attrs", "span")

    def __init__(self, tracer: Tracer, name: str, parent, attrs: dict):
        self.tracer, self.name, self.parent, self.attrs = \
            tracer, name, parent, attrs
        self.span: Optional[Span] = None

    def __enter__(self) -> Span:
        self.span = self.tracer.start_span(self.name, parent=self.parent,
                                           **self.attrs)
        self.tracer._stack.append(self.span)
        return self.span

    def __exit__(self, exc_type, exc, tb) -> None:
        self.tracer._stack.pop()
        if exc_type is not None:
            self.span.attrs.setdefault("error", f"{exc_type.__name__}: {exc}")
        self.tracer.end_span(self.span)
