"""Dependency-free metrics substrate: counters, gauges, histograms, timer.

Design constraints (this is the serving hot path's telemetry, not an APM
suite):

* **Stdlib only.**  The registry must be importable from every layer —
  kernels' host wrappers, the builder, the servers — without dragging in a
  client library the container doesn't have.
* **Monotonic clocks only.**  Every duration here comes from
  ``time.perf_counter()`` via ``Timer``.  ``time.time()`` is wall clock and
  steps under NTP — the seed's serve stats could report *negative*
  latencies after a clock slew.  A CI grep-lint enforces that no
  ``time.time()`` latency math survives in ``repro/serve``.
* **Fixed-bucket histograms.**  Latency histograms use a fixed exponential
  bucket ladder so p50/p95/p99 extraction is O(#buckets), mergeable across
  processes, and *identical math* between the benchmark harness and the
  serve-time exporters (``benchmarks/qps_recall.py`` observes into the same
  ``Histogram``).
* **Labels are first-class but flat.**  A metric family (one name) has
  children keyed by a sorted ``(key, value)`` label tuple — enough for
  ``{shard="3"}`` / ``{status="ok"}`` cardinality, no label matchers.

Observation never raises into the serving path: values are coerced with
``float()`` and NaN observations are dropped (counted in ``n_dropped``).
"""

from __future__ import annotations

import math
import time
from collections import deque
from typing import Optional

# Exponential ladder 100 µs → ~13 s; doubling buckets keep relative
# quantile error ≤ 2× at every scale a CPU-or-TPU batch can land on.
DEFAULT_LATENCY_BUCKETS_S: tuple[float, ...] = tuple(
    1e-4 * (2.0 ** i) for i in range(18)
)

# For device-side work counters surfaced per batch (final_l, hops):
# powers of two up to the largest l_max anyone configures.
DEFAULT_WORK_BUCKETS: tuple[float, ...] = tuple(
    float(2 ** i) for i in range(1, 15)
)

LabelDict = Optional[dict]


def _label_key(labels: LabelDict) -> tuple:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonic counter.  ``inc`` only goes up; decrements raise."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        n = float(n)
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n


class Gauge:
    """Point-in-time value (liveness, queue depth, coverage)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += float(n)

    def dec(self, n: float = 1.0) -> None:
        self.value -= float(n)


class Histogram:
    """Fixed-bucket histogram with Prometheus-style cumulative export and
    interpolated quantile extraction.

    ``bounds`` are the inclusive upper edges of the finite buckets
    (ascending); observations above the last edge land in the +Inf
    overflow bucket.  ``quantile(q)`` walks the cumulative counts and
    linearly interpolates inside the winning bucket; overflow-bucket
    quantiles report the exact observed max (tracked separately) rather
    than pretending +Inf.
    """

    __slots__ = ("bounds", "counts", "overflow", "sum", "count",
                 "min", "max", "n_dropped")

    def __init__(self, bounds: tuple[float, ...] = DEFAULT_LATENCY_BUCKETS_S):
        if not bounds or any(b <= a for a, b in zip(bounds, bounds[1:])):
            raise ValueError(f"bucket bounds must be ascending: {bounds}")
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * len(self.bounds)
        self.overflow = 0
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf
        self.n_dropped = 0

    def observe(self, v: float) -> None:
        v = float(v)
        if math.isnan(v):
            self.n_dropped += 1
            return
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        # linear scan: 18 buckets, branch-predictable; not worth bisect
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.overflow += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def cumulative(self) -> list[tuple[float, int]]:
        """[(upper_edge, cumulative_count)] including the +Inf bucket."""
        out, acc = [], 0
        for b, c in zip(self.bounds, self.counts):
            acc += c
            out.append((b, acc))
        out.append((math.inf, acc + self.overflow))
        return out

    def quantile(self, q: float) -> float:
        """Interpolated q-quantile (q in [0, 1]); 0.0 when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        acc = 0
        lo = 0.0
        for b, c in zip(self.bounds, self.counts):
            if acc + c >= rank and c > 0:
                frac = (rank - acc) / c
                lo_edge = max(lo, self.min if acc == 0 else lo)
                hi_edge = min(b, self.max)
                return lo_edge + frac * max(hi_edge - lo_edge, 0.0)
            acc += c
            lo = b
        # overflow bucket: the honest answer is the tracked max
        return self.max

    def percentiles(self) -> dict[str, float]:
        return {"p50": self.quantile(0.50),
                "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}


class Timer:
    """Monotonic-clock duration capture (``time.perf_counter``).

    Use as a context manager — ``with Timer(hist) as t: ...`` observes the
    elapsed seconds into ``hist`` (if given) on exit and leaves it on
    ``t.elapsed`` — or call ``Timer.now()`` for a raw monotonic timestamp
    where two-point arithmetic is clearer than a ``with`` block.
    """

    __slots__ = ("hist", "start", "elapsed")

    now = staticmethod(time.perf_counter)

    def __init__(self, hist: Optional[Histogram] = None):
        self.hist = hist
        self.start = 0.0
        self.elapsed = 0.0

    def __enter__(self) -> "Timer":
        self.start = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.elapsed = time.perf_counter() - self.start
        if self.hist is not None:
            self.hist.observe(self.elapsed)


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Get-or-create registry of metric families with flat labels.

    A *family* is (name, kind, help, bucket bounds); *children* are the
    per-label-set instances.  Re-requesting a name with a different kind
    raises — a name means one thing for the life of the process.

    ``event(name, **fields)`` appends a structured record (ladder
    transitions, breaker trips, build phases) to a bounded ring and bumps
    the ``{name}_total`` counter, so events are countable in Prometheus
    text and inspectable with payloads in the JSON export.
    """

    def __init__(self, max_events: int = 2048):
        self._families: dict[str, dict] = {}
        self._children: dict[tuple[str, tuple], object] = {}
        self.events: deque = deque(maxlen=max_events)

    # -- family accessors ----------------------------------------------------
    def _get(self, kind: str, name: str, labels: LabelDict, help: str,
             buckets: Optional[tuple[float, ...]] = None):
        fam = self._families.get(name)
        if fam is None:
            fam = {"kind": kind, "help": help,
                   "buckets": buckets or DEFAULT_LATENCY_BUCKETS_S}
            self._families[name] = fam
        elif fam["kind"] != kind:
            raise TypeError(f"metric {name!r} already registered as "
                            f"{fam['kind']}, requested {kind}")
        key = (name, _label_key(labels))
        child = self._children.get(key)
        if child is None:
            child = Histogram(fam["buckets"]) if kind == "histogram" \
                else _KINDS[kind]()
            self._children[key] = child
        return child

    def counter(self, name: str, labels: LabelDict = None,
                help: str = "") -> Counter:
        return self._get("counter", name, labels, help)

    def gauge(self, name: str, labels: LabelDict = None,
              help: str = "") -> Gauge:
        return self._get("gauge", name, labels, help)

    def histogram(self, name: str, labels: LabelDict = None, help: str = "",
                  buckets: Optional[tuple[float, ...]] = None) -> Histogram:
        return self._get("histogram", name, labels, help, buckets)

    def timer(self, name: str, labels: LabelDict = None,
              help: str = "") -> Timer:
        return Timer(self.histogram(name, labels, help))

    # -- structured events ---------------------------------------------------
    def event(self, name: str, **fields) -> dict:
        rec = {"name": name, "t_mono": time.perf_counter(), **fields}
        self.events.append(rec)
        self.counter(f"{name}_total").inc()
        return rec

    # -- iteration (exporters) -----------------------------------------------
    def families(self):
        """Yields (name, kind, help, [(label_tuple, child), ...])."""
        for name, fam in sorted(self._families.items()):
            children = [(lk, c) for (n, lk), c in
                        sorted(self._children.items()) if n == name]
            yield name, fam["kind"], fam["help"], children
