"""Fault-tolerant checkpointing: atomic, manifest-committed, keep-K,
async-capable, checksum-verified, reshard-on-restore.

Layout (one directory per step):
    <dir>/step_000123.tmp/...    (write)
    <dir>/step_000123/           (os.replace — atomic commit)
        manifest.json            {step, n_arrays, keys, dtypes, shapes,
                                  checksums}
        arrays.npz               flattened pytree, path-keyed

Crash safety: a checkpoint is valid iff the non-``.tmp`` directory exists
with a readable manifest — a process killed mid-save leaves only ``.tmp``
junk that the next save cleans up.

Integrity: the manifest records a CRC32 per array (computed from the raw
host bytes at save time).  ``restore_latest`` re-hashes every array on load
and treats any mismatch — like an unreadable archive, a torn manifest, or a
key-set mismatch against the restore template — as "this step is corrupt":
it logs a warning and **walks back to the next-older step** instead of
raising.  A bit-flipped ``arrays.npz`` therefore costs one checkpoint
interval of progress, never the process.  (Pre-checksum checkpoints restore
fine: verification is skipped when the manifest has no ``checksums`` entry.)

Resharding: arrays are saved host-resident (fully replicated view); on
restore the caller passes target shardings (or a template pytree of jax
arrays with shardings) and each leaf is ``device_put`` to its new layout —
this is what makes restarts onto a *different* mesh size work (elastic
world resize, DESIGN.md §6).
"""

from __future__ import annotations

import json
import logging
import os
import re
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")

log = logging.getLogger("repro.checkpoint")


class CheckpointCorruptError(RuntimeError):
    """A single step failed integrity checks (caught by the walk-back)."""


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _checksum(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def save_checkpoint(directory: str, step: int, tree, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "n_arrays": len(flat),
        "keys": sorted(flat.keys()),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "checksums": {k: _checksum(v) for k, v in flat.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _prune(directory, keep)
    return final


def _prune(directory: str, keep: int) -> None:
    steps = sorted(
        (m.group(0) for m in map(_STEP_RE.match, os.listdir(directory)) if m),
    )
    for name in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, name), ignore_errors=True)
    for name in os.listdir(directory):        # clean torn saves
        if name.endswith(".tmp"):
            shutil.rmtree(os.path.join(directory, name), ignore_errors=True)


def list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def _load_verified(path: str, verify: bool) -> dict[str, np.ndarray]:
    """Load one step's arrays, checked against its manifest.  Raises
    ``CheckpointCorruptError`` on any integrity violation."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except Exception as e:
        raise CheckpointCorruptError(f"unreadable manifest: {e}") from e
    try:
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
    except Exception as e:
        raise CheckpointCorruptError(f"unreadable arrays.npz: {e}") from e
    keys = manifest.get("keys")
    if keys is not None and set(keys) != set(flat.keys()):
        raise CheckpointCorruptError(
            f"manifest/arrays key mismatch: {set(keys) ^ set(flat.keys())}")
    checksums = manifest.get("checksums")
    if verify and checksums:
        for k, arr in flat.items():
            expect = checksums.get(k)
            got = _checksum(arr)
            if expect is not None and got != expect:
                raise CheckpointCorruptError(
                    f"checksum mismatch for {k!r}: "
                    f"manifest {expect:#010x} != data {got:#010x}")
    return flat


def restore_latest(directory: str, template, shardings=None, verify: bool = True
                   ) -> tuple[Optional[int], Any]:
    """Restore the newest checkpoint that passes integrity checks into the
    template's structure.  Invalid steps (unreadable, checksum-mismatched,
    or key-set-mismatched vs the template) are logged and skipped — the walk
    continues to the next-older step, and ``(None, template)`` is returned
    only when nothing valid remains.

    ``shardings``: optional pytree (same structure) of jax.sharding.Sharding
    for reshard-on-load; defaults to the template leaves' shardings when the
    template holds jax arrays.  ``verify=False`` skips checksum re-hashing
    (trusted local disk, restore-latency-sensitive callers)."""
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
            for path_, _ in leaves_p]
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(keys))
    for step in reversed(list_steps(directory)):
        path = os.path.join(directory, f"step_{step:09d}")
        try:
            flat = _load_verified(path, verify)
            if set(keys) != set(flat.keys()):
                raise CheckpointCorruptError(
                    f"template structure mismatch: {set(keys) ^ set(flat.keys())}")
        except Exception as e:
            log.warning("skipping checkpoint %s (%s); walking back", path, e)
            continue
        new_leaves = []
        for (pth, tmpl), key, shd in zip(leaves_p, keys, shard_leaves):
            arr = flat[key].astype(tmpl.dtype) if hasattr(tmpl, "dtype") else flat[key]
            if shd is None and isinstance(tmpl, jax.Array) and hasattr(tmpl, "sharding"):
                shd = tmpl.sharding
            new_leaves.append(jax.device_put(arr, shd) if shd is not None
                              else jax.numpy.asarray(arr))
        return step, jax.tree_util.tree_unflatten(treedef, new_leaves)
    return None, template


class CheckpointManager:
    """Periodic (optionally async) checkpointing around a train loop."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.every = every
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree) -> bool:
        if step % self.every != 0:
            return False
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self._thread = threading.Thread(
                target=save_checkpoint,
                args=(self.directory, step, host_tree, self.keep), daemon=True)
            self._thread.start()
        else:
            save_checkpoint(self.directory, step, host_tree, self.keep)
        return True

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, template, shardings=None, verify: bool = True):
        return restore_latest(self.directory, template, shardings, verify)
