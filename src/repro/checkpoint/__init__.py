from .manager import (  # noqa: F401
    CheckpointCorruptError,
    CheckpointManager,
    list_steps,
    restore_latest,
    save_checkpoint,
)
