"""Deterministic synthetic data generators (offline container — no dataset
downloads).  Every generator is a pure function of (seed, step), which makes
the data pipeline *resumable by construction*: after a restart the loader
replays exactly the batches after the checkpointed step, no cursor files.

Generators:
  * clustered_vectors — SIFT-like vector corpora for the ANN core: Gaussian
    mixture with overlapping clusters + a uniform noise floor (LID roughly
    tunable via scale / n_clusters).
  * make_markov_lm / lm_batch — a fixed sparse Markov chain over the vocab
    (each token has ``branch`` successors).  A trained LM should approach
    ln(branch) nats — giving the 100M-param example a real learning signal.
  * recsys_ctr_batch / recsys_seq_batch — click logs with planted latent
    factors so CTR/retrieval models have learnable structure.
  * sbm_graph — stochastic-block-model graph (cora-like) with community
    labels; molecule_batch — batched small random graphs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


# ---------------------------------------------------------------------------
# Vectors (ANN core)
# ---------------------------------------------------------------------------

def clustered_vectors(n: int, dim: int, n_clusters: int = 64,
                      scale: float = 0.35, noise_frac: float = 0.05,
                      seed: int = 0) -> np.ndarray:
    """Overlapping GMM + uniform noise floor; unit-ish norm spread."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, dim)).astype(np.float32)
    n_noise = int(n * noise_frac)
    asg = rng.integers(0, n_clusters, n - n_noise)
    pts = centers[asg] + scale * rng.normal(size=(n - n_noise, dim))
    noise = rng.normal(size=(n_noise, dim)) * 1.2
    out = np.concatenate([pts, noise]).astype(np.float32)
    rng.shuffle(out)
    return out


# ---------------------------------------------------------------------------
# LM: sparse Markov chain language
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MarkovLM:
    succ: np.ndarray      # int32[V, branch] successor table
    vocab: int
    branch: int

    def entropy(self) -> float:
        return float(np.log(self.branch))


def make_markov_lm(vocab: int, branch: int = 4, seed: int = 0) -> MarkovLM:
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, size=(vocab, branch)).astype(np.int32)
    return MarkovLM(succ=succ, vocab=vocab, branch=branch)


def lm_batch(lm: MarkovLM, batch: int, seq: int, step: int,
             seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """→ (tokens int32[batch, seq], targets int32[batch, seq])."""
    rng = np.random.default_rng((seed, step))
    toks = np.empty((batch, seq + 1), np.int32)
    toks[:, 0] = rng.integers(0, lm.vocab, batch)
    choices = rng.integers(0, lm.branch, size=(batch, seq))
    for t in range(seq):
        toks[:, t + 1] = lm.succ[toks[:, t], choices[:, t]]
    return toks[:, :-1], toks[:, 1:]


# ---------------------------------------------------------------------------
# RecSys click logs with planted latent factors
# ---------------------------------------------------------------------------

def recsys_ctr_batch(batch: int, step: int, n_dense: int = 13,
                     n_sparse: int = 26, rows: int = 1 << 21,
                     latent_dim: int = 8, seed: int = 0) -> dict:
    """CTR batch: label = σ(⟨planted user factor, planted item factor⟩)."""
    rng = np.random.default_rng((seed, step))
    dense = rng.normal(size=(batch, n_dense)).astype(np.float32)
    sparse = rng.integers(0, rows, size=(batch, n_sparse)).astype(np.int32)
    # planted structure: hash sparse ids into latent space
    phase = (sparse[:, :latent_dim] % 97).astype(np.float32) / 97.0
    score = np.sum(np.cos(2 * np.pi * phase), axis=1) + 0.5 * dense[:, 0]
    prob = 1.0 / (1.0 + np.exp(-score))
    label = (rng.random(batch) < prob).astype(np.float32)
    return {"dense": dense, "sparse_ids": sparse, "label": label}


def recsys_seq_batch(batch: int, step: int, n_items: int, n_cats: int = 4096,
                     seq_len: int = 100, n_neg: int = 16,
                     n_interest_clusters: int = 128, seed: int = 0) -> dict:
    """Sequential behavior logs: each user samples from 1–3 item clusters;
    the positive target comes from one of them (retrievable structure)."""
    rng = np.random.default_rng((seed, step))
    cluster_size = max(n_items // n_interest_clusters, 1)
    user_clusters = rng.integers(0, n_interest_clusters, size=(batch, 3))
    pick = rng.integers(0, 3, size=(batch, seq_len))
    base = user_clusters[np.arange(batch)[:, None], pick]
    hist = (base * cluster_size
            + rng.integers(0, cluster_size, (batch, seq_len))).astype(np.int32)
    hist = np.minimum(hist, n_items - 1)
    lengths = rng.integers(seq_len // 2, seq_len + 1, batch)
    mask = np.arange(seq_len)[None, :] < lengths[:, None]
    tgt_cluster = user_clusters[np.arange(batch), rng.integers(0, 3, batch)]
    target = np.minimum(tgt_cluster * cluster_size
                        + rng.integers(0, cluster_size, batch),
                        n_items - 1).astype(np.int32)
    neg = rng.integers(0, n_items, size=(batch, n_neg)).astype(np.int32)
    label = rng.integers(0, 2, batch).astype(np.float32)
    return {
        "hist_items": hist,
        "hist_cats": (hist % n_cats).astype(np.int32),
        "hist_mask": mask,
        "target_item": target,
        "target_cat": (target % n_cats).astype(np.int32),
        "neg_items": neg,
        "label": label,
    }


# ---------------------------------------------------------------------------
# Graphs
# ---------------------------------------------------------------------------

def sbm_graph(n_nodes: int, n_comms: int, d_feat: int, avg_degree: float = 4.0,
              p_in_frac: float = 0.9, seed: int = 0) -> dict:
    """Stochastic block model with community labels + noisy indicator feats."""
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, n_comms, n_nodes).astype(np.int32)
    n_edges = int(n_nodes * avg_degree)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    same = rng.random(n_edges) < p_in_frac
    # in-community targets: random node of the same community via rejection
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    # cheap same-community rewire: sort nodes by community, pick neighbor slots
    order = np.argsort(comm, kind="stable")
    starts = np.searchsorted(comm[order], np.arange(n_comms))
    ends = np.searchsorted(comm[order], np.arange(n_comms) + 1)
    cs = comm[src]
    lo, hi = starts[cs], np.maximum(ends[cs], starts[cs] + 1)
    in_comm = order[(lo + rng.integers(0, 1 << 30, n_edges) % np.maximum(hi - lo, 1))]
    dst = np.where(same, in_comm, dst).astype(np.int32)
    feats = (np.eye(n_comms, dtype=np.float32)[comm][:, :d_feat]
             if d_feat <= n_comms else None)
    if feats is None:
        feats = np.zeros((n_nodes, d_feat), np.float32)
        feats[np.arange(n_nodes), comm % d_feat] = 1.0
    feats = feats + 0.3 * rng.normal(size=feats.shape).astype(np.float32)
    # symmetrize
    src2 = np.concatenate([src, dst])
    dst2 = np.concatenate([dst, src])
    return {"x": feats, "src": src2.astype(np.int32),
            "dst": dst2.astype(np.int32), "labels": comm,
            "n_classes": n_comms}


def molecule_batch(batch: int, nodes_per_graph: int, edges_per_graph: int,
                   d_feat: int, n_classes: int, step: int, seed: int = 0) -> dict:
    """Block-diagonal batch of small random graphs; label = parity of a
    planted motif count (learnable)."""
    rng = np.random.default_rng((seed, step))
    N = batch * nodes_per_graph
    x = rng.normal(size=(N, d_feat)).astype(np.float32)
    src = np.concatenate([
        rng.integers(0, nodes_per_graph, edges_per_graph) + g * nodes_per_graph
        for g in range(batch)
    ]).astype(np.int32)
    dst = np.concatenate([
        rng.integers(0, nodes_per_graph, edges_per_graph) + g * nodes_per_graph
        for g in range(batch)
    ]).astype(np.int32)
    graph_ids = np.repeat(np.arange(batch), nodes_per_graph).astype(np.int32)
    feat_sum = x.reshape(batch, nodes_per_graph, d_feat).sum((1, 2))
    labels = ((feat_sum > 0).astype(np.int32)) % n_classes
    return {"x": x, "src": src, "dst": dst, "graph_ids": graph_ids,
            "labels": labels, "node_mask": np.ones(N, bool)}
