from .synthetic import (  # noqa: F401
    clustered_vectors,
    lm_batch,
    make_markov_lm,
    recsys_ctr_batch,
    recsys_seq_batch,
    sbm_graph,
    molecule_batch,
)
from .sampler import CSRGraph, fanout_sample  # noqa: F401
