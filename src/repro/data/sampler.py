"""CSR graph container + fanout neighbor sampler (GraphSAGE-style) for the
``minibatch_lg`` GNN cell.  Host-side numpy — samplers are irregular and
feed the accelerator with fixed-shape padded subgraphs.

``fanout_sample`` returns a two-hop (configurable) sampled subgraph with
locally re-indexed, padded edge arrays, ready for ``gnn.forward``.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray     # int64[n+1]
    indices: np.ndarray    # int32[nnz]

    @property
    def n_nodes(self) -> int:
        return self.indptr.shape[0] - 1

    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> "CSRGraph":
        order = np.argsort(dst, kind="stable")
        s = src[order].astype(np.int32)
        d = dst[order]
        indptr = np.searchsorted(d, np.arange(n_nodes + 1)).astype(np.int64)
        return CSRGraph(indptr=indptr, indices=s)

    def sample_neighbors(self, nodes: np.ndarray, fanout: int,
                         rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
        """Up to ``fanout`` in-neighbors per node → (src, dst) edge arrays."""
        srcs, dsts = [], []
        for v in nodes:
            lo, hi = self.indptr[v], self.indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            k = min(fanout, int(deg))
            sel = rng.choice(int(deg), size=k, replace=False)
            srcs.append(self.indices[lo + sel])
            dsts.append(np.full(k, v, np.int32))
        if not srcs:
            return np.empty(0, np.int32), np.empty(0, np.int32)
        return np.concatenate(srcs), np.concatenate(dsts)


def fanout_sample(graph: CSRGraph, feats: np.ndarray, labels: np.ndarray,
                  batch_nodes: np.ndarray, fanouts: tuple[int, ...],
                  seed: int = 0, pad_nodes: int | None = None,
                  pad_edges: int | None = None) -> dict:
    """Multi-hop fanout sampling with local re-indexing and fixed-shape
    padding.  Returns x/src/dst/labels/label_mask arrays (padded slots get
    src=dst=-1 and label_mask False)."""
    rng = np.random.default_rng(seed)
    frontier = batch_nodes.astype(np.int32)
    all_src, all_dst = [], []
    seen = dict((int(v), i) for i, v in enumerate(frontier))
    order = list(frontier)
    for f in fanouts:
        s, d = graph.sample_neighbors(np.unique(frontier), f, rng)
        all_src.append(s)
        all_dst.append(d)
        nxt = []
        for v in s:
            if int(v) not in seen:
                seen[int(v)] = len(order)
                order.append(int(v))
                nxt.append(int(v))
        frontier = np.asarray(nxt, np.int32) if nxt else np.empty(0, np.int32)
        if frontier.size == 0:
            break
    src = np.concatenate(all_src) if all_src else np.empty(0, np.int32)
    dst = np.concatenate(all_dst) if all_dst else np.empty(0, np.int32)
    remap = np.vectorize(seen.__getitem__, otypes=[np.int64])
    src_l = remap(src).astype(np.int32) if src.size else src
    dst_l = remap(dst).astype(np.int32) if dst.size else dst
    nodes = np.asarray(order, np.int64)

    n_sub, e_sub = nodes.size, src_l.size
    pad_nodes = pad_nodes or n_sub
    pad_edges = pad_edges or e_sub
    x = np.zeros((pad_nodes, feats.shape[1]), np.float32)
    x[:n_sub] = feats[nodes[:pad_nodes]]
    ps = np.full(pad_edges, -1, np.int32)
    pd = np.full(pad_edges, -1, np.int32)
    ps[:min(e_sub, pad_edges)] = src_l[:pad_edges]
    pd[:min(e_sub, pad_edges)] = dst_l[:pad_edges]
    lab = np.zeros(pad_nodes, np.int32)
    lab[:n_sub] = labels[nodes[:pad_nodes]]
    lmask = np.zeros(pad_nodes, bool)
    lmask[:batch_nodes.size] = True        # supervise only the seed nodes
    return {"x": x, "src": ps, "dst": pd, "labels": lab, "label_mask": lmask,
            "n_sub_nodes": n_sub, "n_sub_edges": e_sub}
