"""Jitted wrapper for the flash-attention kernel: GQA broadcast, sequence
padding to the block size, layout [B,S,H,hd] ⇄ [B·H,S,hd], interpret
fallback on CPU, and the ``use_ref`` escape hatch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flashattn import flash_attention_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bk", "use_ref", "interpret"))
def flash_attention(q, k, v, causal: bool = True, window=None,
                    bq: int = 512, bk: int = 512, use_ref: bool = False,
                    interpret: bool | None = None):
    """q [B,S,H,hd], k/v [B,S,KV,hd] → [B,S,H,hd] (GQA broadcast inside)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    groups = H // KV
    kb = jnp.repeat(k, groups, axis=2)
    vb = jnp.repeat(v, groups, axis=2)
    if use_ref:
        return ref.attention_ref(q, kb, vb, causal=causal, window=window)
    interp = _on_cpu() if interpret is None else interpret
    bq = min(bq, max(8, S))
    bk = min(bk, max(8, S))
    pad = (-S) % max(bq, bk)
    qt = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kt = jnp.pad(kb, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vt = jnp.pad(vb, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S_pad = S + pad
    # [B, S, H, hd] → [B·H, S, hd]
    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S_pad, hd)
    out = flash_attention_pallas(
        to_bh(qt), to_bh(kt), to_bh(vt), seq_len=S, causal=causal,
        window=window, bq=bq, bk=bk, interpret=interp)
    out = out.reshape(B, H, S_pad, hd).transpose(0, 2, 1, 3)[:, :S]
    return out
