"""Pure-jnp oracle for the flash-attention kernel (naive full-matrix)."""

from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True, window=None):
    """q [B,S,H,hd], k/v [B,S,H,hd] (already GQA-broadcast) → [B,S,H,hd].

    Full S×S score matrix in f32 — the correctness oracle the kernel's
    online-softmax must match.
    """
    B, S, H, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask[None, None], p, 0.0)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
