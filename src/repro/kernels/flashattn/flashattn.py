"""Pallas TPU flash-attention (forward) — the §Perf-identified lever for the
LM memory term.

The pure-jnp blockwise attention in ``models/common.py`` spills its
online-softmax state (acc, m, l) to HBM on every kv-block scan step — the
loop-aware roofline shows that traffic dominating every LM train/prefill
cell.  This kernel keeps the state in VMEM scratch across the kv-block grid
dimension, so HBM traffic drops to the ideal
``nq·(S·hd)`` K/V stream + one Q/O pass.

Structure (standard TPU flash decomposition):
  grid = (B·H, n_q_blocks, n_k_blocks)   — kv innermost, iterated
                                            sequentially per (bh, qi)
  q/o blocks   (1, bq, hd)  indexed by (bh, qi)
  k/v blocks   (1, bk, hd)  indexed by (bh, ki)
  scratch      acc (bq, hd) f32 · m (bq, 1) f32 · l (bq, 1) f32  (VMEM)

Masking (causal / sliding-window / S-padding) is computed from global
positions via iota inside the kernel; fully-masked kv blocks are skipped
with ``pl.when``.  GQA is handled by the wrapper (KV broadcast to H).

VMEM per step at bq=bk=512, hd=128: q+k+v+o ≈ 512 KiB + scratch 260 KiB —
double-buffered comfortably inside 16 MiB.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, bq: int, bk: int, seq_len: int,
                  causal: bool, window):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk

    # skip kv blocks that are entirely masked out
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window is not None:
        # block is dead if its newest key is older than the window's edge
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                    # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                    # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < seq_len
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                                 # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bk", "seq_len", "interpret"))
def flash_attention_pallas(q, k, v, seq_len: int, causal: bool = True,
                           window=None, bq: int = 512, bk: int = 512,
                           interpret: bool = False):
    """q/k/v [BH, S_pad, hd] (S_pad % bq == S_pad % bk == 0, KV already
    broadcast to H) → out [BH, S_pad, hd]."""
    BH, S_pad, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    grid = (BH, S_pad // bq, S_pad // bk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, bq=bq, bk=bk, seq_len=seq_len,
        causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
