"""Jitted public wrappers for the bitdot / fused-estimate kernels.

Handles row-tile padding, INVALID_ID masking, interpret fallback on CPU and
the ``use_ref`` escape hatch.  ``bitdot`` has the exact signature
``core.rabitq.estimate_sqdist`` expects for its ``bitdot_fn`` plug.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .bitdot import bitdot_pallas, fused_estimate_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_rows(x: jax.Array, tm: int) -> jax.Array:
    pad = (-x.shape[0]) % tm
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("tm", "use_ref", "interpret"))
def bitdot(codes: jax.Array, q_unit: jax.Array, tm: int = 256,
           use_ref: bool = False, interpret: bool | None = None) -> jax.Array:
    """codes uint32[m, W], q_unit f32[d] → S₊ f32[m]."""
    if use_ref:
        return ref.bitdot_ref(codes, q_unit)
    interp = _on_cpu() if interpret is None else interpret
    m, W = codes.shape
    tm = min(tm, max(8, m))
    q_pad = jnp.pad(q_unit.astype(jnp.float32), (0, 32 * W - q_unit.shape[0]))
    out = bitdot_pallas(_pad_rows(codes, tm), q_pad, tm=tm, interpret=interp)
    return out[:m]


@functools.partial(jax.jit, static_argnames=("dim", "tm", "use_ref", "interpret"))
def fused_estimate(codes: jax.Array, norms: jax.Array, ip_xo: jax.Array,
                   q_unit: jax.Array, norm_q: jax.Array, dim: int,
                   tm: int = 256, use_ref: bool = False,
                   interpret: bool | None = None) -> jax.Array:
    """Fused RaBitQ d² estimate.  codes uint32[m, W], norms/ip_xo f32[m]."""
    if use_ref:
        return ref.estimate_sqdist_ref(codes, norms, ip_xo, q_unit, norm_q, dim)
    interp = _on_cpu() if interpret is None else interpret
    m, W = codes.shape
    tm = min(tm, max(8, m))
    q_pad = jnp.pad(q_unit.astype(jnp.float32), (0, 32 * W - q_unit.shape[0]))
    out = fused_estimate_pallas(
        _pad_rows(codes, tm), _pad_rows(norms.astype(jnp.float32), tm),
        _pad_rows(ip_xo.astype(jnp.float32), tm), q_pad,
        norm_q.astype(jnp.float32), dim, tm=tm, interpret=interp)
    return out[:m]
