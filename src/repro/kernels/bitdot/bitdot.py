"""Pallas TPU kernel: packed RaBitQ sign-code contraction (FastScan analogue).

The CPU paper evaluates RaBitQ estimates with AVX2 FastScan (4-bit LUT
shuffles over transposed code layouts).  The TPU-native replacement keeps
the 1-bit/dim packing in HBM (32× compression is what makes the code table
HBM-resident at billion scale) and converts compute to what the TPU is good
at:

  1. VPU bit-unpack:  uint32[m, W] → {0,1} f32[m, 32·W] via broadcast-iota
     shifts — ~3 VPU ops per 32 dims, no LUTs needed;
  2. MXU contraction: bits[m, d] @ q[d]  →  S₊[m].

``fused_estimate`` additionally applies the RaBitQ estimator algebra
(norms / ip_xo / norm_q scalars) inside the same kernel so the serving hot
loop reads HBM exactly once per code row and writes one f32 per candidate.

Tiling: grid over row-tiles of ``TM`` codes; per-step VMEM =
TM·W·4 (codes) + TM·32W·4 (unpacked) + 32W·4 (query) ≈ 0.6 MiB at
TM=1024, d=128 — comfortably double-bufferable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_tile(codes):
    """uint32 (TM, W) → f32 (TM, 32·W) of {0,1}."""
    TM, W = codes.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (TM, W, 32), 2)
    bits = (codes[:, :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(TM, W * 32).astype(jnp.float32)


def _bitdot_kernel(q_ref, codes_ref, out_ref):
    bits = _unpack_tile(codes_ref[...])
    out_ref[:, 0] = jnp.dot(bits, q_ref[0], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def bitdot_pallas(codes: jax.Array, q_pad: jax.Array, tm: int = 256,
                  interpret: bool = False) -> jax.Array:
    """codes uint32[m, W] (m % tm == 0), q_pad f32[32·W] → S₊ f32[m]."""
    m, W = codes.shape
    out = pl.pallas_call(
        _bitdot_kernel,
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((1, 32 * W), lambda i: (0, 0)),
            pl.BlockSpec((tm, W), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tm, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(q_pad[None, :], codes)
    return out[:, 0]


def _fused_estimate_kernel(q_ref, scal_ref, codes_ref, norms_ref, ipxo_ref,
                           out_ref):
    bits = _unpack_tile(codes_ref[...])
    s_plus = jnp.dot(bits, q_ref[0], preferred_element_type=jnp.float32)
    sum_q = scal_ref[0, 0]
    norm_q = scal_ref[0, 1]
    inv_sqrt_d = scal_ref[0, 2]
    ip_xq = (2.0 * s_plus - sum_q) * inv_sqrt_d
    est_cos = ip_xq / jnp.maximum(ipxo_ref[:, 0], 1e-6)
    nv = norms_ref[:, 0]
    d2 = nv * nv + norm_q * norm_q - 2.0 * nv * norm_q * est_cos
    out_ref[:, 0] = jnp.maximum(d2, 0.0)


@functools.partial(jax.jit, static_argnames=("dim", "tm", "interpret"))
def fused_estimate_pallas(codes: jax.Array, norms: jax.Array, ip_xo: jax.Array,
                          q_pad: jax.Array, norm_q: jax.Array, dim: int,
                          tm: int = 256, interpret: bool = False) -> jax.Array:
    """Full RaBitQ distance estimate in one pass.  codes uint32[m, W]
    (m % tm == 0), norms/ip_xo f32[m], q_pad f32[32·W] → est d² f32[m]."""
    m, W = codes.shape
    scal = jnp.stack([jnp.sum(q_pad), norm_q,
                      1.0 / jnp.sqrt(jnp.float32(dim))])[None, :]
    out = pl.pallas_call(
        _fused_estimate_kernel,
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((1, 32 * W), lambda i: (0, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
            pl.BlockSpec((tm, W), lambda i: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tm, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(q_pad[None, :], scal, codes, norms[:, None], ip_xo[:, None])
    return out[:, 0]
