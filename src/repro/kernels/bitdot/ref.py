"""Pure-jnp oracle for the bitdot (RaBitQ FastScan-analogue) kernel."""

from __future__ import annotations

import jax.numpy as jnp


def unpack_bits_ref(codes: jnp.ndarray, dim: int) -> jnp.ndarray:
    """uint32[m, W] → f32[m, dim] of {0, 1} bit values."""
    m, W = codes.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bits = (codes[:, :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(m, W * 32)[:, :dim].astype(jnp.float32)


def bitdot_ref(codes: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """S₊[i] = Σ_{j: bit_ij = 1} q_j   —  codes uint32[m, W], q f32[d]."""
    bits = unpack_bits_ref(codes, q.shape[0])
    return bits @ q.astype(jnp.float32)


def estimate_sqdist_ref(codes, norms, ip_xo, q_unit, norm_q, dim) -> jnp.ndarray:
    """Fused RaBitQ estimator oracle (matches core.rabitq.estimate_sqdist)."""
    s_plus = bitdot_ref(codes, q_unit)
    sum_q = jnp.sum(q_unit)
    ip_xq = (2.0 * s_plus - sum_q) / jnp.sqrt(jnp.float32(dim))
    est_cos = ip_xq / jnp.maximum(ip_xo, 1e-6)
    d2 = norms * norms + norm_q * norm_q - 2.0 * norms * norm_q * est_cos
    return jnp.maximum(d2, 0.0)
