"""Pallas TPU kernels for the ANN distance hot path.

Two kernels, matching the two halves of a graph-search expansion:

``batched_l2``  — contraction:  rows f32[B, M, d] × queries f32[B, d]
                  → squared distances f32[B, M].
                  One grid step per query; the (M, d) neighbor tile and the
                  (1, d) query line live in VMEM; the cross term r·q is an
                  (M, d) × (d,) MXU contraction (dims padded to lane width
                  by the wrapper), the norm terms are VPU reductions.
                  VMEM per step ≈ M·d·4B (64×128 → 32 KiB) ≪ 16 MiB.

``gather_l2``   — fused gather + distance via scalar-prefetch indexing:
                  the neighbor-id array is prefetched into SMEM, and the
                  BlockSpec index_map picks base row ``ids[b, m]`` for grid
                  step (b, m) — HBM→VMEM DMA of exactly the needed row,
                  Pallas double-buffers successive rows.  This is the
                  TPU-native replacement for the CPU's pointer-chasing
                  per-neighbor loads; the wrapper clamps INVALID ids to row
                  0 and masks the output.

``gather_l2_tiled`` — the beam-engine hot path.  The single-row variant
                  issues one latency-bound DMA per grid step ((1, d) blocks);
                  the tiled variant keeps the base matrix in HBM
                  (``memory_space=ANY``), and each grid step launches
                  ``block_rows`` row DMAs back-to-back into a VMEM scratch
                  tile before a single vectorized (R, d) distance reduction —
                  R in-flight copies amortize DMA issue latency and the
                  compute runs on a full tile instead of one row.  VMEM per
                  step is R·d·4 B (8×128 → 4 KiB) plus the (1, d) query line.

Validated on CPU in interpret mode against ``ref.py``; compiled path is
exercised structurally by the dry-run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# batched_l2: rows [B, M, d] × queries [B, d] → d2 [B, M]
# ---------------------------------------------------------------------------

def _batched_l2_kernel(q_ref, rows_ref, out_ref):
    rows = rows_ref[0]                       # (M, d) VMEM tile
    q = q_ref[0]                             # (d,)
    rq = jnp.dot(rows, q, preferred_element_type=jnp.float32)   # MXU
    r2 = jnp.sum(rows * rows, axis=-1)                          # VPU
    q2 = jnp.sum(q * q)
    out_ref[0, :] = jnp.maximum(r2 + q2 - 2.0 * rq, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def batched_l2_pallas(rows: jax.Array, queries: jax.Array,
                      interpret: bool = False) -> jax.Array:
    B, M, d = rows.shape
    return pl.pallas_call(
        _batched_l2_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, d), lambda b: (b, 0)),
            pl.BlockSpec((1, M, d), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, M), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.float32),
        interpret=interpret,
    )(queries.astype(jnp.float32), rows.astype(jnp.float32))


# ---------------------------------------------------------------------------
# gather_l2: base [n, d] + ids [B, M] + queries [B, d] → d2 [B, M]
# ---------------------------------------------------------------------------

def _gather_l2_kernel(ids_ref, base_row_ref, q_ref, out_ref):
    del ids_ref  # consumed by the index_map; kernel body only sees the row
    diff = base_row_ref[0] - q_ref[0]
    out_ref[0, 0] = jnp.sum(diff * diff)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_l2_pallas(base: jax.Array, ids: jax.Array, queries: jax.Array,
                     interpret: bool = False) -> jax.Array:
    B, M = ids.shape
    n, d = base.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, M),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, m, ids: (ids[b, m], 0)),
            pl.BlockSpec((1, d), lambda b, m, ids: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, m, ids: (b, m)),
    )
    return pl.pallas_call(
        _gather_l2_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.float32),
        interpret=interpret,
    )(ids.astype(jnp.int32), base.astype(jnp.float32),
      queries.astype(jnp.float32))


# ---------------------------------------------------------------------------
# gather_l2_tiled: base [n, d] + ids [B, M] + queries [B, d] → d2 [B, M],
# R = block_rows gathered rows per grid step.
# ---------------------------------------------------------------------------

def _gather_l2_tiled_kernel(ids_ref, base_hbm, q_ref, out_ref, rows_vmem,
                            sems, *, block_rows: int):
    b = pl.program_id(0)
    t = pl.program_id(1)
    R = block_rows

    def row_dma(r):
        row = ids_ref[b, t * R + r]
        return pltpu.make_async_copy(
            base_hbm.at[pl.ds(row, 1), :],
            rows_vmem.at[pl.ds(r, 1), :],
            sems.at[r],
        )

    def start(r, _):
        row_dma(r).start()
        return 0

    def wait(r, _):
        row_dma(r).wait()
        return 0

    # Launch all R row copies, then drain: R DMAs in flight per grid step.
    jax.lax.fori_loop(0, R, start, 0)
    jax.lax.fori_loop(0, R, wait, 0)

    diff = rows_vmem[...] - q_ref[0][None, :]
    out_ref[0, :] = jnp.sum(diff * diff, axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gather_l2_tiled_pallas(base: jax.Array, ids: jax.Array, queries: jax.Array,
                           block_rows: int = 8,
                           interpret: bool = False) -> jax.Array:
    B, M = ids.shape
    n, d = base.shape
    if M % block_rows:
        raise ValueError(f"M={M} must be a multiple of block_rows={block_rows}"
                         " (wrapper pads)")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, M // block_rows),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),           # base stays in HBM
            pl.BlockSpec((1, d), lambda b, t, ids: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows), lambda b, t, ids: (b, t)),
        scratch_shapes=[
            pltpu.VMEM((block_rows, d), jnp.float32),
            pltpu.SemaphoreType.DMA((block_rows,)),
        ],
    )
    kernel = functools.partial(_gather_l2_tiled_kernel, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, M), jnp.float32),
        interpret=interpret,
    )(ids.astype(jnp.int32), base.astype(jnp.float32),
      queries.astype(jnp.float32))
