"""Jitted public wrappers for the l2dist kernels.

Handles: lane-width padding (d → multiple of 128), INVALID_ID clamping and
masking, interpret-mode fallback on CPU, and an env/flag escape hatch to the
pure-jnp reference (``use_ref=True``) so higher layers can A/B the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .l2dist import batched_l2_pallas, gather_l2_pallas, gather_l2_tiled_pallas

_LANE = 128


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_lane(x: jax.Array, axis: int) -> jax.Array:
    d = x.shape[axis]
    pad = (-d) % _LANE
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("use_ref", "interpret"))
def batched_l2(rows: jax.Array, queries: jax.Array, use_ref: bool = False,
               interpret: bool | None = None) -> jax.Array:
    """rows f32[B, M, d], queries f32[B, d] → squared L2 f32[B, M]."""
    if use_ref:
        return ref.batched_l2_ref(rows, queries)
    interp = _on_cpu() if interpret is None else interpret
    rows_p = _pad_lane(rows, 2)
    q_p = _pad_lane(queries, 1)
    return batched_l2_pallas(rows_p, q_p, interpret=interp)


@functools.partial(jax.jit, static_argnames=("use_ref", "interpret"))
def gather_l2(base: jax.Array, ids: jax.Array, queries: jax.Array,
              use_ref: bool = False, interpret: bool | None = None) -> jax.Array:
    """base f32[n, d], ids int32[B, M] (INVALID→+inf), queries f32[B, d]."""
    safe = jnp.maximum(ids, 0)
    if use_ref:
        d2 = ref.gather_l2_ref(base, safe, queries)
    else:
        interp = _on_cpu() if interpret is None else interpret
        d2 = gather_l2_pallas(_pad_lane(base, 1), safe, _pad_lane(queries, 1),
                              interpret=interp)
    return jnp.where(ids >= 0, d2, jnp.inf)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "use_ref", "interpret"))
def gather_l2_tiled(base: jax.Array, ids: jax.Array, queries: jax.Array,
                    block_rows: int = 8, use_ref: bool = False,
                    interpret: bool | None = None) -> jax.Array:
    """Tiled fused gather+L2: ``block_rows`` row DMAs per grid step.

    Same contract as :func:`gather_l2`; M is padded up to a multiple of
    ``block_rows`` internally (pad rows index row 0 and are masked out).
    """
    B, M = ids.shape
    safe = jnp.maximum(ids, 0)
    if use_ref:
        d2 = ref.gather_l2_ref(base, safe, queries)
    else:
        interp = _on_cpu() if interpret is None else interpret
        pad = (-M) % block_rows
        if pad:
            safe = jnp.pad(safe, ((0, 0), (0, pad)))
        d2 = gather_l2_tiled_pallas(_pad_lane(base, 1), safe,
                                    _pad_lane(queries, 1),
                                    block_rows=block_rows, interpret=interp)
        d2 = d2[:, :M]
    return jnp.where(ids >= 0, d2, jnp.inf)
