"""Pure-jnp oracle for the l2dist kernels."""

from __future__ import annotations

import jax.numpy as jnp


def batched_l2_ref(rows: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """rows f32[B, M, d], queries f32[B, d] → squared L2 f32[B, M]."""
    diff = rows.astype(jnp.float32) - queries.astype(jnp.float32)[:, None, :]
    return jnp.sum(diff * diff, axis=-1)


def gather_l2_ref(base: jnp.ndarray, ids: jnp.ndarray, queries: jnp.ndarray) -> jnp.ndarray:
    """base f32[n, d], ids int32[B, M] (≥0), queries f32[B, d] → f32[B, M]."""
    rows = jnp.take(base, ids, axis=0)  # [B, M, d]
    return batched_l2_ref(rows, queries)
