"""Pallas TPU kernels for the paper's compute hot-spots.

The paper's performance section optimizes exactly one thing: distance
evaluation during graph traversal (exact L2 + RaBitQ/FastScan approximate).
Hence two kernel families:

    l2dist/   — batched/fused-gather squared-L2 (exact tier)
    bitdot/   — packed 1-bit RaBitQ code contraction + fused estimator
                (approximate tier; TPU-native FastScan replacement)
    flashattn/ — flash attention fwd with VMEM-resident online-softmax
                 state (the §Perf-identified lever for the LM memory term)

Each provides  <name>.py (pl.pallas_call + BlockSpec),  ops.py (jitted
wrapper w/ CPU interpret fallback),  ref.py (pure-jnp oracle).
"""

from .l2dist.ops import batched_l2, gather_l2  # noqa: F401
from .bitdot.ops import bitdot, fused_estimate  # noqa: F401
from .flashattn.ops import flash_attention as flash_attention_kernel  # noqa: F401
