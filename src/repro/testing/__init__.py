from .faults import (  # noqa: F401
    FaultPlan,
    KernelFault,
    flip_bits,
    inject_search_faults,
    make_torn_tmp,
    tamper_array,
    tear_checkpoint,
)
