from .oracle import check_delta_bound, exact_knn, recall_at_k  # noqa: F401
from .faults import (  # noqa: F401
    FaultPlan,
    KernelFault,
    RepairFault,
    RepairFaultPlan,
    ShardDeathPlan,
    SimulatedCrash,
    corrupt_shard_source,
    crash_at,
    flip_bits,
    inject_search_faults,
    inject_shard_deaths,
    make_torn_tmp,
    tamper_array,
    tear_checkpoint,
    torn_wal_record,
)
