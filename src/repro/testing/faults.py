"""Deterministic fault injection for the serve + checkpoint stack.

Resilience claims are only as good as the faults they were tested against,
and "unplug the TPU" is not a unit test.  This module injects the failure
modes the resilience layer (``repro.serve.resilience``) and the checkpoint
walk-back (``repro.checkpoint.manager``) are built to contain, each one
deterministic and seedable so CI reproduces exactly:

* **Search faults** — ``inject_search_faults`` wraps a server's
  ``_search`` seam with a ``FaultPlan``: raise ``KernelFault`` on chosen
  calls (optionally only for a given engine/backend tier, which is how a
  "Pallas kernel is broken, XLA is fine" scenario is staged) and/or add
  latency spikes.
* **Checkpoint corruption** — ``flip_bits`` (raw bit flips anywhere in a
  file, e.g. ``arrays.npz``), ``tamper_array`` (perturb one stored array
  while keeping the manifest byte-identical → exercises checksum
  verification specifically), ``tear_checkpoint`` (drop the manifest →
  invalid step), ``make_torn_tmp`` (a ``.tmp`` directory as left by a
  process killed mid-save).

Nothing here is imported by production code paths — faults flow only
test → harness → server seam.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Optional

import numpy as np


class KernelFault(RuntimeError):
    """Injected stand-in for an accelerator kernel failure."""


@dataclasses.dataclass
class FaultPlan:
    """Deterministic schedule of faults on the search seam.

    ``fail_first`` fails the first N *matching* calls (matching = the
    engine/backend filters, when set); ``fail_calls`` additionally fails
    those matching-call indices (0-based).  ``latency_s`` sleeps before
    every matching call (``latency_calls`` restricts it to given indices).
    """

    fail_first: int = 0
    fail_calls: tuple[int, ...] = ()
    match_engine: Optional[str] = None      # None → any engine
    match_backend: Optional[str] = None     # None → any backend
    exc_type: type = KernelFault
    latency_s: float = 0.0
    latency_calls: Optional[tuple[int, ...]] = None   # None → every call

    def should_fail(self, match_idx: int) -> bool:
        return match_idx < self.fail_first or match_idx in self.fail_calls

    def delay_for(self, match_idx: int) -> float:
        if self.latency_s <= 0:
            return 0.0
        if self.latency_calls is not None and match_idx not in self.latency_calls:
            return 0.0
        return self.latency_s


class inject_search_faults:
    """Context manager wrapping ``server._search`` with a ``FaultPlan``.

    Counts calls (total and plan-matching) for assertions::

        with inject_search_faults(srv, FaultPlan(fail_first=2)) as inj:
            srv.submit_many(queries)
            responses = srv.drain()
        assert inj.n_failed == 2
    """

    def __init__(self, server, plan: FaultPlan):
        self.server = server
        self.plan = plan
        self.n_calls = 0
        self.n_matched = 0
        self.n_failed = 0
        self._orig = None

    def _matches(self, engine: str, backend: str) -> bool:
        return ((self.plan.match_engine is None
                 or engine == self.plan.match_engine)
                and (self.plan.match_backend is None
                     or backend == self.plan.match_backend))

    def __enter__(self):
        self._orig = self.server._search
        plan = self.plan

        def wrapped(queries, params=None, engine=None, backend=None):
            self.n_calls += 1
            eng = engine if engine is not None else self.server.engine
            bck = backend if backend is not None else self.server.backend
            if self._matches(eng, bck):
                idx = self.n_matched
                self.n_matched += 1
                delay = plan.delay_for(idx)
                if delay > 0:
                    time.sleep(delay)
                if plan.should_fail(idx):
                    self.n_failed += 1
                    raise plan.exc_type(
                        f"injected fault #{idx} on tier {eng}/{bck}")
            return self._orig(queries, params=params, engine=engine,
                              backend=backend)

        self.server._search = wrapped
        return self

    def __exit__(self, *exc):
        self.server._search = self._orig
        return False


# ---------------------------------------------------------------------------
# Checkpoint corruption.
# ---------------------------------------------------------------------------


def flip_bits(path: str, n_bits: int = 8, seed: int = 0) -> list[int]:
    """Flip ``n_bits`` deterministic bits in a file; returns byte offsets.

    Offsets are drawn from the middle half of the file so small files keep
    their zip local headers intact more often than not — but any outcome
    (unreadable archive, checksum mismatch, silent data change) must be
    contained by the restore walk-back, so callers should assert on the
    *recovery*, not on which layer caught it.
    """
    rng = np.random.default_rng(seed)
    with open(path, "rb") as f:
        data = bytearray(f.read())
    if not data:
        raise ValueError(f"cannot flip bits in empty file: {path}")
    lo, hi = len(data) // 4, max(len(data) // 4 + 1, 3 * len(data) // 4)
    offsets = sorted(int(o) for o in rng.integers(lo, hi, size=n_bits))
    for off in offsets:
        data[off] ^= 1 << int(rng.integers(0, 8))
    with open(path, "wb") as f:
        f.write(bytes(data))
    return offsets


def tamper_array(step_dir: str, key: Optional[str] = None,
                 amount: float = 1.0) -> str:
    """Perturb one array inside ``arrays.npz``, leaving the manifest (and
    therefore its recorded checksums) untouched — the restore path must
    catch this via checksum verification, not via a load error.  Returns
    the tampered key."""
    npz = os.path.join(step_dir, "arrays.npz")
    with np.load(npz) as z:
        flat = {k: z[k].copy() for k in z.files}
    if key is None:
        key = sorted(flat.keys())[0]
    arr = flat[key]
    if arr.size == 0:
        raise ValueError(f"array {key!r} is empty, nothing to tamper")
    if np.issubdtype(arr.dtype, np.floating):
        arr.flat[arr.size // 2] += amount
    else:
        arr.flat[arr.size // 2] ^= 1
    np.savez(npz, **flat)
    return key


def tear_checkpoint(step_dir: str) -> None:
    """Invalidate a committed checkpoint the way a torn write would:
    remove its manifest (a step without a readable manifest is never
    listed as restorable)."""
    os.remove(os.path.join(step_dir, "manifest.json"))


def make_torn_tmp(directory: str, step: int) -> str:
    """Recreate the on-disk state of a process killed mid-save: a
    ``step_XXXXXXXXX.tmp`` directory holding a partial manifest and a
    truncated ``arrays.npz``.  The next committed save must prune it and
    ``restore_latest`` must never consider it."""
    tmp = os.path.join(directory, f"step_{step:09d}.tmp")
    os.makedirs(tmp, exist_ok=True)
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        f.write(b"PK\x03\x04truncated-mid-write")
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        f.write(json.dumps({"step": step})[:-5])    # torn JSON
    return tmp
