"""Deterministic fault injection for the serve + checkpoint stack.

Resilience claims are only as good as the faults they were tested against,
and "unplug the TPU" is not a unit test.  This module injects the failure
modes the resilience layer (``repro.serve.resilience``) and the checkpoint
walk-back (``repro.checkpoint.manager``) are built to contain, each one
deterministic and seedable so CI reproduces exactly:

* **Search faults** — ``inject_search_faults`` wraps a server's
  ``_search`` seam with a ``FaultPlan``: raise ``KernelFault`` on chosen
  calls (optionally only for a given engine/backend tier, which is how a
  "Pallas kernel is broken, XLA is fine" scenario is staged) and/or add
  latency spikes.
* **Checkpoint corruption** — ``flip_bits`` (raw bit flips anywhere in a
  file, e.g. ``arrays.npz``), ``tamper_array`` (perturb one stored array
  while keeping the manifest byte-identical → exercises checksum
  verification specifically), ``tear_checkpoint`` (drop the manifest →
  invalid step), ``make_torn_tmp`` (a ``.tmp`` directory as left by a
  process killed mid-save).
* **WAL crash points** — ``crash_at(point)`` builds the ``fault_hook`` a
  ``JournaledLiveIndex`` accepts: raise ``SimulatedCrash`` at a named
  protocol point (``before_journal`` / ``torn_journal`` / ``after_journal``
  / ``mid_splice``), optionally only on the Nth visit.  ``torn_wal_record``
  tears an already-committed record post-hoc (truncated payload +
  checksum-stale manifest) — the shape a crash during a *later* append
  leaves behind.
* **Shard death** — ``ShardDeathPlan`` drives a
  ``ShardHealthRegistry`` from a call schedule (kill shard s before call i,
  revive at call j) so coverage-degradation sequences replay exactly.
* **Repair faults** — ``RepairFaultPlan`` builds the ``fault_hook`` a
  ``core.repair.RepairController`` accepts: ``RepairFault`` on rebuild
  visits (contained → backoff + retry) and ``SimulatedCrash`` at an
  install-phase point (uncontained → proves the atomic-install rule).
  ``corrupt_shard_source`` tampers a ``ShardVectorStore`` shard post-hoc
  so the CRC verify-on-read path is exercised.

Nothing here is imported by production code paths — faults flow only
test → harness → server seam.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Optional

import numpy as np


class KernelFault(RuntimeError):
    """Injected stand-in for an accelerator kernel failure."""


@dataclasses.dataclass
class FaultPlan:
    """Deterministic schedule of faults on the search seam.

    ``fail_first`` fails the first N *matching* calls (matching = the
    engine/backend filters, when set); ``fail_calls`` additionally fails
    those matching-call indices (0-based).  ``latency_s`` sleeps before
    every matching call (``latency_calls`` restricts it to given indices).
    """

    fail_first: int = 0
    fail_calls: tuple[int, ...] = ()
    match_engine: Optional[str] = None      # None → any engine
    match_backend: Optional[str] = None     # None → any backend
    match_min_beam_width: Optional[int] = None  # only calls with W ≥ this
    exc_type: type = KernelFault
    latency_s: float = 0.0
    latency_calls: Optional[tuple[int, ...]] = None   # None → every call

    def should_fail(self, match_idx: int) -> bool:
        return match_idx < self.fail_first or match_idx in self.fail_calls

    def delay_for(self, match_idx: int) -> float:
        if self.latency_s <= 0:
            return 0.0
        if self.latency_calls is not None and match_idx not in self.latency_calls:
            return 0.0
        return self.latency_s


class inject_search_faults:
    """Context manager wrapping ``server._search`` with a ``FaultPlan``.

    Counts calls (total and plan-matching) for assertions, and records the
    ``(engine, backend, beam_width)`` tier of *every* call in ``tier_log``
    so tests can assert the exact fallback ladder a fault sequence walked —
    e.g. that the circuit breaker bottoms out at ``("beam", "jnp", 1)``::

        with inject_search_faults(srv, FaultPlan(fail_first=2)) as inj:
            srv.submit_many(queries)
            responses = srv.drain()
        assert inj.n_failed == 2
        assert inj.tier_log[-1] == ("beam", "jnp", 1)
    """

    def __init__(self, server, plan: FaultPlan):
        self.server = server
        self.plan = plan
        self.n_calls = 0
        self.n_matched = 0
        self.n_failed = 0
        self.tier_log: list[tuple] = []   # (engine, backend, beam_width)
        self._orig = None

    def _matches(self, engine: str, backend: str,
                 beam_width: Optional[int] = None) -> bool:
        p = self.plan
        if p.match_engine is not None and engine != p.match_engine:
            return False
        if p.match_backend is not None and backend != p.match_backend:
            return False
        if (p.match_min_beam_width is not None and beam_width is not None
                and beam_width < p.match_min_beam_width):
            return False
        return True

    def __enter__(self):
        self._orig = self.server._search
        plan = self.plan

        def wrapped(queries, params=None, engine=None, backend=None):
            self.n_calls += 1
            eng = engine if engine is not None else self.server.engine
            bck = backend if backend is not None else self.server.backend
            p = params if params is not None else self.server.params
            self.tier_log.append((eng, bck, getattr(p, "beam_width", None)))
            if self._matches(eng, bck, getattr(p, "beam_width", None)):
                idx = self.n_matched
                self.n_matched += 1
                delay = plan.delay_for(idx)
                if delay > 0:
                    time.sleep(delay)
                if plan.should_fail(idx):
                    self.n_failed += 1
                    raise plan.exc_type(
                        f"injected fault #{idx} on tier {eng}/{bck}")
            return self._orig(queries, params=params, engine=engine,
                              backend=backend)

        self.server._search = wrapped
        return self

    def __exit__(self, *exc):
        self.server._search = self._orig
        return False


# ---------------------------------------------------------------------------
# Checkpoint corruption.
# ---------------------------------------------------------------------------


def flip_bits(path: str, n_bits: int = 8, seed: int = 0) -> list[int]:
    """Flip ``n_bits`` deterministic bits in a file; returns byte offsets.

    Offsets are drawn from the middle half of the file so small files keep
    their zip local headers intact more often than not — but any outcome
    (unreadable archive, checksum mismatch, silent data change) must be
    contained by the restore walk-back, so callers should assert on the
    *recovery*, not on which layer caught it.
    """
    rng = np.random.default_rng(seed)
    with open(path, "rb") as f:
        data = bytearray(f.read())
    if not data:
        raise ValueError(f"cannot flip bits in empty file: {path}")
    lo, hi = len(data) // 4, max(len(data) // 4 + 1, 3 * len(data) // 4)
    offsets = sorted(int(o) for o in rng.integers(lo, hi, size=n_bits))
    for off in offsets:
        data[off] ^= 1 << int(rng.integers(0, 8))
    with open(path, "wb") as f:
        f.write(bytes(data))
    return offsets


def tamper_array(step_dir: str, key: Optional[str] = None,
                 amount: float = 1.0) -> str:
    """Perturb one array inside ``arrays.npz``, leaving the manifest (and
    therefore its recorded checksums) untouched — the restore path must
    catch this via checksum verification, not via a load error.  Returns
    the tampered key."""
    npz = os.path.join(step_dir, "arrays.npz")
    with np.load(npz) as z:
        flat = {k: z[k].copy() for k in z.files}
    if key is None:
        key = sorted(flat.keys())[0]
    arr = flat[key]
    if arr.size == 0:
        raise ValueError(f"array {key!r} is empty, nothing to tamper")
    if np.issubdtype(arr.dtype, np.floating):
        arr.flat[arr.size // 2] += amount
    else:
        arr.flat[arr.size // 2] ^= 1
    np.savez(npz, **flat)
    return key


def tear_checkpoint(step_dir: str) -> None:
    """Invalidate a committed checkpoint the way a torn write would:
    remove its manifest (a step without a readable manifest is never
    listed as restorable)."""
    os.remove(os.path.join(step_dir, "manifest.json"))


def make_torn_tmp(directory: str, step: int) -> str:
    """Recreate the on-disk state of a process killed mid-save: a
    ``step_XXXXXXXXX.tmp`` directory holding a partial manifest and a
    truncated ``arrays.npz``.  The next committed save must prune it and
    ``restore_latest`` must never consider it."""
    tmp = os.path.join(directory, f"step_{step:09d}.tmp")
    os.makedirs(tmp, exist_ok=True)
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        f.write(b"PK\x03\x04truncated-mid-write")
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        f.write(json.dumps({"step": step})[:-5])    # torn JSON
    return tmp


# ---------------------------------------------------------------------------
# WAL crash points (streaming-update journal).
# ---------------------------------------------------------------------------


class SimulatedCrash(RuntimeError):
    """Raised by a crash hook — models the process dying at that point."""


def crash_at(point: str, on_visit: int = 0):
    """Build a ``fault_hook`` that raises ``SimulatedCrash`` the
    ``on_visit``-th time the named protocol point is reached (other points
    pass through).  The hook carries ``.visits`` for assertions."""
    state = {"visits": 0}

    def hook(p: str) -> None:
        if p != point:
            return
        v = state["visits"]
        state["visits"] += 1
        if v == on_visit:
            raise SimulatedCrash(f"crash at {point} (visit {v})")

    hook.point = point
    hook.state = state
    return hook


def torn_wal_record(wal_dir: str, seq: int, mode: str = "truncate") -> None:
    """Corrupt an already-committed WAL record post-hoc.

    ``mode="truncate"`` halves the payload npz (unreadable archive);
    ``mode="checksum"`` rewrites the payload with one element perturbed
    while the manifest keeps the stale CRC.  Either way ``wal_read`` must
    raise ``WalCorruptError`` and replay must stop *before* this record.
    """
    base = os.path.join(wal_dir, f"wal_{seq:09d}")
    npz = base + ".npz"
    if mode == "truncate":
        with open(npz, "rb") as f:
            data = f.read()
        with open(npz, "wb") as f:
            f.write(data[: max(1, len(data) // 2)])
    elif mode == "checksum":
        with np.load(npz) as z:
            flat = {k: z[k].copy() for k in z.files}
        key = sorted(flat)[0]
        arr = flat[key]
        if arr.size == 0:
            raise ValueError(f"array {key!r} empty, nothing to perturb")
        if np.issubdtype(arr.dtype, np.floating):
            arr.flat[0] += 1.0
        else:
            arr.flat[0] ^= 1
        np.savez(npz, **flat)
    else:
        raise ValueError(f"unknown mode: {mode!r}")


# ---------------------------------------------------------------------------
# Shard death schedules (distributed serving).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardDeathPlan:
    """Deterministic shard liveness schedule, applied before each call.

    ``kill[(shard, replica)] = i`` kills that slot before the i-th call;
    ``revive[(shard, replica)] = j`` revives it before the j-th call.
    Drive it manually (``apply(registry, call_idx)``) or let
    ``inject_shard_deaths`` hook a ``ShardedResilientAnnServer``.
    """

    kill: dict = dataclasses.field(default_factory=dict)
    revive: dict = dataclasses.field(default_factory=dict)

    def apply(self, registry, call_idx: int) -> None:
        for (s, r), i in self.kill.items():
            if call_idx >= i:
                registry.mark_dead(s, r)
        for (s, r), j in self.revive.items():
            if call_idx >= j:
                registry.mark_live(s, r)


class inject_shard_deaths:
    """Context manager applying a ``ShardDeathPlan`` around a sharded
    server's ``_search`` seam (same wrapping discipline as
    ``inject_search_faults``)."""

    def __init__(self, server, plan: ShardDeathPlan):
        self.server = server
        self.plan = plan
        self.n_calls = 0
        self._orig = None

    def __enter__(self):
        self._orig = self.server._search

        def wrapped(queries, params=None, engine=None, backend=None):
            self.plan.apply(self.server.registry, self.n_calls)
            self.n_calls += 1
            return self._orig(queries, params=params, engine=engine,
                              backend=backend)

        self.server._search = wrapped
        return self

    def __exit__(self, *exc):
        self.server._search = self._orig
        return False


# ---------------------------------------------------------------------------
# Shard repair faults (core.repair).
# ---------------------------------------------------------------------------


class RepairFault(RuntimeError):
    """Injected failure inside the repair controller's contained phase."""


_REPAIR_CONTAINED = ("load_source", "rebuild")
_REPAIR_CRASH_POINTS = ("before_install", "mid_install", "after_install")


@dataclasses.dataclass
class RepairFaultPlan:
    """Deterministic schedule for a ``RepairController``'s ``fault_hook``.

    Two distinct failure classes, matching the controller's two phases:

    * **contained failures** — ``fail_rebuilds`` raises ``RepairFault`` on
      the first N visits to the ``rebuild`` point (``fail_rebuild_visits``
      adds specific 0-based visit indices); the controller must catch
      these, back off, and retry — coverage stays down but never regresses.
    * **install crashes** — ``crash_point`` (one of ``before_install`` /
      ``mid_install`` / ``after_install``) raises ``SimulatedCrash`` on its
      ``crash_on_visit``-th visit.  These model the process dying in the
      UNcontained phase: the exception propagates out of ``sweep`` and the
      test asserts the atomic-install rule — the participation mask never
      flips for a repair whose install did not complete.  Crash points in
      the contained phase are rejected (``ValueError``): the controller
      would swallow them as an ordinary repair failure, silently testing
      nothing.

    ``hook()`` builds the actual ``fault_hook`` and tracks per-point visit
    counts in ``visits`` for assertions.
    """

    fail_rebuilds: int = 0
    fail_rebuild_visits: tuple[int, ...] = ()
    crash_point: Optional[str] = None
    crash_on_visit: int = 0

    def __post_init__(self):
        if (self.crash_point is not None
                and self.crash_point not in _REPAIR_CRASH_POINTS):
            raise ValueError(
                f"crash_point must be one of {_REPAIR_CRASH_POINTS} (the "
                f"uncontained install phase), got {self.crash_point!r}")

    def hook(self):
        visits: dict[str, int] = {}

        def fault_hook(point: str) -> None:
            v = visits.get(point, 0)
            visits[point] = v + 1
            if point == "rebuild" and (v < self.fail_rebuilds
                                       or v in self.fail_rebuild_visits):
                raise RepairFault(f"injected rebuild failure (visit {v})")
            if point == self.crash_point and v == self.crash_on_visit:
                raise SimulatedCrash(f"crash at {point} (visit {v})")

        fault_hook.visits = visits
        return fault_hook


def corrupt_shard_source(store_dir: str, shard: int,
                         mode: str = "checksum") -> None:
    """Corrupt one shard's durable vector source post-hoc (same shapes as
    ``torn_wal_record``): ``"truncate"`` halves the npz, ``"checksum"``
    perturbs one element while the manifest keeps the stale CRC.  Either
    way ``ShardVectorStore.load_shard`` must raise
    ``ShardSourceCorruptError`` and the repair must fail *cleanly* — no
    install, no mark_live, wrong data never serves."""
    npz = os.path.join(store_dir, f"shard_{shard:04d}.npz")
    if mode == "truncate":
        with open(npz, "rb") as f:
            data = f.read()
        with open(npz, "wb") as f:
            f.write(data[: max(1, len(data) // 2)])
    elif mode == "checksum":
        with np.load(npz) as z:
            flat = {k: z[k].copy() for k in z.files}
        flat["rows"].flat[0] += 1.0
        np.savez(npz, **flat)
    else:
        raise ValueError(f"unknown mode: {mode!r}")
