"""Implementation-independent correctness oracles for graph ANN search.

The paper's central claim is *provable*: any greedy search on a δ-EMG
returns a ``(1/δ)``-approximate nearest neighbor, and the adaptive α-stop
rule (Alg. 3) tightens that to ``1/(δ·α)``.  That makes the right test
oracle brute-force exact k-NN **plus the bound itself** — not another
approximate engine.  Engine-vs-engine parity is circular (both engines can
share a bug); the bound is what the theorems guarantee and is checkable
per query against ground truth no search implementation touches.

Everything here is plain numpy on purpose: no jax, no shared kernels, no
shared distance code with the engines under test.  ``exact_knn`` is the
O(n·B·d) ground truth; ``check_delta_bound`` asserts the per-query,
per-rank approximation bound; ``recall_at_k`` is the softer diagnostic
used by non-guaranteed searches (AGS runs on approximate distances, so
only its *rerank* is exact and the δ-bound does not apply verbatim).

Used by ``tests/test_conformance.py`` (marker ``conformance``) across
every engine/backend/beam_width combination.
"""

from __future__ import annotations

from typing import Optional

import numpy as np


def exact_knn(corpus: np.ndarray, queries: np.ndarray, k: int
              ) -> tuple[np.ndarray, np.ndarray]:
    """Brute-force exact k-NN: (dists f64[B, k], ids int64[B, k]).

    Euclidean distances, ascending per row; ties broken by lower id
    (``np.argsort`` kind="stable" over the full row).  float64 throughout
    so the oracle is strictly more precise than the f32 engines it judges.
    """
    corpus = np.asarray(corpus, np.float64)
    queries = np.asarray(queries, np.float64)
    if k < 1 or k > corpus.shape[0]:
        raise ValueError(f"k={k} out of range for corpus of {corpus.shape[0]}")
    d2 = np.sum((queries[:, None, :] - corpus[None, :, :]) ** 2, axis=-1)
    ids = np.argsort(d2, axis=1, kind="stable")[:, :k]
    dists = np.sqrt(np.take_along_axis(d2, ids, axis=1))
    return dists, ids


def check_delta_bound(returned_dists: np.ndarray, oracle_dists: np.ndarray,
                      delta: float, alpha: float = 1.0,
                      atol: float = 1e-4) -> Optional[str]:
    """Per-query, per-rank approximation bound check.

    Asserts ``returned_dists[b, i] ≤ (1 / (δ·α)) · oracle_dists[b, i] + atol``
    for every query b and every rank i < k — the Theorem-1 guarantee (α = 1
    for plain greedy search; pass the search α to use the tighter Alg.-3
    bound, valid only for queries whose adaptive loop actually fired the
    α-rule, i.e. ``saturated=False``).

    Returns ``None`` when the bound holds everywhere, else a human-readable
    description of the worst violation (query, rank, distances, factor) —
    tests ``assert check_delta_bound(...) is None`` so failures print it.

    ``atol`` absorbs f32-vs-f64 noise and the exact-hit case
    (``oracle_dist == 0`` ⇒ the returned dist must also be ~0).
    """
    if not 0.0 < delta:
        raise ValueError(f"delta must be positive, got {delta}")
    ret = np.asarray(returned_dists, np.float64)
    orc = np.asarray(oracle_dists, np.float64)
    if ret.shape != orc.shape:
        raise ValueError(f"shape mismatch: returned {ret.shape} vs "
                         f"oracle {orc.shape}")
    factor = 1.0 / (delta * max(alpha, 1.0))
    limit = factor * orc + atol
    bad = ret > limit
    if not bad.any():
        return None
    excess = np.where(bad, ret - limit, -np.inf)
    b, i = np.unravel_index(np.argmax(excess), excess.shape)
    return (f"δ-bound violated for {int(bad.sum())}/{bad.size} entries; "
            f"worst at query {b} rank {i}: returned {ret[b, i]:.6g} > "
            f"{factor:.4g}·{orc[b, i]:.6g} + {atol:g} "
            f"(ratio {ret[b, i] / max(orc[b, i], 1e-30):.4g}, "
            f"bound factor {factor:.4g})")


def recall_at_k(returned_ids: np.ndarray, oracle_ids: np.ndarray) -> float:
    """Mean fraction of true k-NN ids recovered per query (set overlap)."""
    ret = np.asarray(returned_ids)
    orc = np.asarray(oracle_ids)
    hits = sum(len(set(r.tolist()) & set(o.tolist()))
               for r, o in zip(ret, orc))
    return hits / float(orc.size)
