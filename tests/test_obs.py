"""Observability layer: histogram math, span nesting, exporters, and the
observation-only invariant (metrics on vs off must be bit-identical).

The acceptance surface here is deliberately wide: the metric names are
stable API (README §Observability), so the exporter tests grep for the
exact families an operator's dashboards would scrape."""

import json
import math

import numpy as np
import pytest

from repro.core import SearchParams, build_exact
from repro.obs import (
    DEFAULT_LATENCY_BUCKETS_S,
    DEFAULT_WORK_BUCKETS,
    Histogram,
    MetricsRegistry,
    PeriodicSummary,
    Timer,
    Tracer,
    declare_serve_metrics,
    snapshot,
    summary_line,
    to_json,
    to_prometheus,
)
from repro.serve import AnnServer, ResilienceConfig, ResilientAnnServer

PARAMS = SearchParams(k=5, l0=8, l_max=64, alpha=1.4, adaptive=True,
                      max_hops=512, beam_width=4)


@pytest.fixture(scope="module")
def tiny():
    rng = np.random.default_rng(11)
    base = rng.normal(size=(300, 16)).astype(np.float32)
    with pytest.warns(UserWarning):          # degree cap on a dense corpus
        graph = build_exact(base, delta=0.15, max_degree=12)
    queries = rng.normal(size=(48, 16)).astype(np.float32)
    return {"graph": graph, "queries": queries}


# ---------------------------------------------------------------------------
# Histogram math.
# ---------------------------------------------------------------------------


def test_histogram_bucket_placement():
    h = Histogram(bounds=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 3.0, 100.0):
        h.observe(v)
    assert h.counts == [2, 1, 1]             # upper edges are inclusive
    assert h.overflow == 1
    assert h.count == 5
    assert h.min == 0.5 and h.max == 100.0
    # cumulative export ends with the +Inf bucket covering everything
    cum = h.cumulative()
    assert cum[-1] == (math.inf, 5)
    assert [c for _, c in cum] == sorted(c for _, c in cum)


def test_histogram_quantiles_track_numpy_within_bucket_resolution():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-6.0, sigma=1.0, size=5000)  # ms-scale latencies
    h = Histogram()                           # default latency ladder
    for v in vals:
        h.observe(float(v))
    for q in (0.5, 0.95, 0.99):
        est = h.quantile(q)
        exact = float(np.percentile(vals, 100 * q))
        # doubling buckets ⇒ interpolated estimate within one bucket (2×)
        assert exact / 2 <= est <= exact * 2, (q, est, exact)


def test_histogram_overflow_quantile_reports_observed_max():
    h = Histogram(bounds=(1.0,))
    h.observe(5.0)
    h.observe(7.5)
    assert h.quantile(0.99) == 7.5            # not +Inf, not the edge


def test_histogram_nan_dropped_not_raised():
    h = Histogram(bounds=(1.0,))
    h.observe(float("nan"))
    h.observe(0.5)
    assert h.count == 1 and h.n_dropped == 1


def test_histogram_empty_and_validation():
    assert Histogram().quantile(0.5) == 0.0
    with pytest.raises(ValueError):
        Histogram(bounds=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram().quantile(1.5)


# ---------------------------------------------------------------------------
# Registry: counters, gauges, labels, events, timer.
# ---------------------------------------------------------------------------


def test_counter_monotone_gauge_not():
    r = MetricsRegistry()
    c = r.counter("reqs_total")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = r.gauge("depth")
    g.set(4)
    g.dec()
    assert g.value == 3.0


def test_labels_create_distinct_children_and_get_or_create():
    r = MetricsRegistry()
    a = r.counter("resp_total", {"status": "ok"})
    b = r.counter("resp_total", {"status": "failed"})
    a.inc(3)
    assert b.value == 0
    # same labels in any order → the same child object
    r2 = r.counter("resp_total", {"status": "ok"})
    assert r2 is a


def test_kind_conflict_raises():
    r = MetricsRegistry()
    r.counter("x_total")
    with pytest.raises(TypeError):
        r.gauge("x_total")


def test_event_ring_and_auto_counter():
    r = MetricsRegistry(max_events=2)
    r.event("ladder_step", rung=1, reason="queue_depth=70")
    r.event("ladder_step", rung=2, reason="queue_depth=90")
    r.event("ladder_step", rung=1, reason="drained")
    assert len(r.events) == 2                 # bounded ring
    assert r.events[-1]["reason"] == "drained"
    assert r.counter("ladder_step_total").value == 3


def test_timer_observes_elapsed():
    r = MetricsRegistry()
    with r.timer("op_seconds") as t:
        pass
    assert t.elapsed >= 0
    assert r.histogram("op_seconds").count == 1
    assert Timer.now() > 0


# ---------------------------------------------------------------------------
# Tracing: nesting, explicit parents, retroactive spans.
# ---------------------------------------------------------------------------


def test_lexical_spans_nest():
    tr = Tracer()
    with tr.span("batch") as b:
        with tr.span("execute") as e:
            pass
    assert e.parent_id == b.span_id
    assert b.parent_id is None
    assert [s.name for s in tr.children_of(b)] == ["execute"]
    assert all(s.finished for s in tr.finished)


def test_explicit_parent_beats_stack_and_activate_bridges():
    tr = Tracer()
    root = tr.start_span("root")
    with tr.span("other"):
        child = tr.start_span("child", parent=root)   # explicit wins
    assert child.parent_id == root.span_id
    # activate/deactivate: non-lexical parenting across a call boundary
    tr.activate(root)
    inner = tr.start_span("fanout")
    tr.deactivate(root)
    assert inner.parent_id == root.span_id


def test_retroactive_end_and_ring_bound():
    tr = Tracer(max_spans=2)
    s = tr.start_span("request")
    s.start = 10.0
    tr.end_span(s, end=12.5)
    assert s.duration_s == 2.5
    tr.end_span(s, end=99.0)                  # double-end is a no-op
    assert s.end == 12.5
    for i in range(3):
        tr.end_span(tr.start_span(f"s{i}"))
    assert len(tr.finished) == 2              # bounded


# ---------------------------------------------------------------------------
# Exporters.
# ---------------------------------------------------------------------------


def test_prometheus_text_format():
    r = MetricsRegistry()
    r.counter("resp_total", {"status": "ok"}, help="responses").inc(4)
    h = r.histogram("lat_seconds", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    txt = to_prometheus(r)
    assert "# TYPE resp_total counter" in txt
    assert 'resp_total{status="ok"} 4.0' in txt
    assert 'lat_seconds_bucket{le="0.1"} 1' in txt
    assert 'lat_seconds_bucket{le="+Inf"} 2' in txt
    assert "lat_seconds_count 2" in txt
    assert 'lat_seconds{quantile="0.95"}' in txt


def test_json_snapshot_round_trip():
    r = MetricsRegistry()
    r.counter("c_total").inc(2)
    r.gauge("g").set(0.5)
    r.histogram("h_seconds", buckets=(1.0,)).observe(0.3)
    r.event("evt", detail="x")
    tr = Tracer()
    tr.end_span(tr.start_span("request", seq=0))
    snap = json.loads(to_json(r, tr))
    assert snap["counters"]["c_total"] == 2.0
    assert snap["counters"]["evt_total"] == 1.0
    assert snap["gauges"]["g"] == 0.5
    assert snap["histograms"]["h_seconds"]["count"] == 1
    assert snap["histograms"]["h_seconds"]["p50"] >= 0
    assert snap["events"][0]["detail"] == "x"
    assert snap["spans"][0]["name"] == "request"
    # exporting is read-only: a second export is identical
    assert to_json(r, tr) == to_json(r, tr)


def test_summary_line_and_periodic_gate():
    r = declare_serve_metrics(MetricsRegistry())
    r.histogram("serve_request_latency_seconds").observe(0.004)
    line = summary_line(r)
    assert line.startswith("[obs] req=1")
    # injectable clock: emits once per interval, force overrides
    t = {"now": 0.0}
    out = []

    class _S:
        def write(self, s):
            out.append(s)

        def flush(self):
            pass

    ps = PeriodicSummary(r, 10.0, stream=_S(), clock=lambda: t["now"])
    assert ps.tick() is None                  # interval not elapsed
    t["now"] = 11.0
    assert ps.tick() is not None
    assert ps.tick() is None                  # gated again
    assert ps.tick(force=True) is not None


def test_declared_schema_covers_acceptance_families():
    snap = snapshot(declare_serve_metrics(MetricsRegistry(), n_shards=2))
    hists, gauges, counters = (snap["histograms"], snap["gauges"],
                               snap["counters"])
    for h in ("serve_request_latency_seconds", "serve_queue_wait_seconds",
              "wal_append_seconds", "wal_fsync_seconds"):
        assert h in hists, h
    assert 'shard_live{shard="0"}' in gauges
    assert 'shard_live{shard="1"}' in gauges
    assert "shard_coverage" in gauges
    for c in ("search_dist_comps_total", "search_hops_total",
              'serve_responses_total{status="ok"}'):
        assert c in counters, c
    assert any(k.startswith("serve_degradation_transitions_total")
               for k in counters)


# ---------------------------------------------------------------------------
# Instrumented serving: taxonomy populated, spans linked, results unchanged.
# ---------------------------------------------------------------------------


def test_ann_server_populates_taxonomy_and_spans(tiny):
    m, tr = MetricsRegistry(), Tracer()
    srv = AnnServer(tiny["graph"], PARAMS, max_batch=32, buckets=(32,),
                    metrics=m, tracer=tr)
    srv.submit_many(tiny["queries"])
    out = srv.drain()
    n = len(tiny["queries"])
    assert len(out) == n
    snap = snapshot(m)
    assert snap["histograms"]["serve_request_latency_seconds"]["count"] == n
    assert snap["histograms"]["serve_queue_wait_seconds"]["count"] == n
    assert snap["counters"]['serve_responses_total{status="ok"}'] == n
    assert snap["counters"]["search_dist_comps_total"] > 0
    assert snap["counters"]["search_hops_total"] > 0
    assert snap["histograms"]["search_final_l"]["count"] == n
    # spans: every request span has a queue-wait child; batches decompose
    reqs = tr.by_name("serve.request")
    assert len(reqs) == n
    for rs in reqs[:4]:
        kids = tr.children_of(rs)
        assert [k.name for k in kids] == ["serve.queue_wait"]
        assert kids[0].end <= rs.end
    batches = tr.by_name("serve.batch")
    assert len(batches) == srv.stats.n_batches
    names = {s.name for b in batches for s in tr.children_of(b)}
    assert {"serve.batch_form", "serve.device_execute",
            "serve.merge"} <= names


def test_pad_rows_not_double_billed(tiny):
    """A 5-request batch padded to bucket 32 must aggregate device counters
    over 5 rows, not 32."""
    m = MetricsRegistry()
    srv = AnnServer(tiny["graph"], PARAMS, max_batch=32, buckets=(32,),
                    metrics=m)
    srv.submit_many(tiny["queries"][:5])
    srv.drain()
    assert m.histogram("search_final_l",
                       buckets=DEFAULT_WORK_BUCKETS).count == 5


def _ids_dists(out):
    return (np.stack([np.asarray(i) for i, _ in out]),
            np.stack([np.asarray(d) for _, d in out]))


def test_metrics_on_vs_off_bit_identical_plain(tiny):
    off = AnnServer(tiny["graph"], PARAMS, max_batch=32, buckets=(32,))
    on = AnnServer(tiny["graph"], PARAMS, max_batch=32, buckets=(32,),
                   metrics=declare_serve_metrics(MetricsRegistry()),
                   tracer=Tracer())
    off.submit_many(tiny["queries"])
    on.submit_many(tiny["queries"])
    ids0, d0 = _ids_dists(off.drain())
    ids1, d1 = _ids_dists(on.drain())
    np.testing.assert_array_equal(ids0, ids1)
    np.testing.assert_array_equal(d0, d1)     # bit-identical, not allclose


def test_metrics_on_vs_off_bit_identical_resilient(tiny):
    cfg = ResilienceConfig(backoff_s=0.0)
    off = ResilientAnnServer(tiny["graph"], PARAMS, config=cfg,
                             max_batch=32, buckets=(32,))
    on = ResilientAnnServer(tiny["graph"], PARAMS, config=cfg,
                            max_batch=32, buckets=(32,),
                            metrics=declare_serve_metrics(MetricsRegistry()),
                            tracer=Tracer())
    off.submit_many(tiny["queries"])
    on.submit_many(tiny["queries"])
    r0, r1 = off.drain(), on.drain()
    assert all(r.ok for r in r0) and all(r.ok for r in r1)
    np.testing.assert_array_equal(np.stack([r.ids for r in r0]),
                                  np.stack([r.ids for r in r1]))
    np.testing.assert_array_equal(np.stack([r.dists for r in r0]),
                                  np.stack([r.dists for r in r1]))


def test_resilient_ladder_transitions_recorded(tiny):
    """Overload → the ladder steps down; the transition must land as a
    labeled counter + a structured event carrying the δ bound."""
    m = MetricsRegistry()
    srv = ResilientAnnServer(
        tiny["graph"], PARAMS,
        config=ResilienceConfig(degrade_depth=8, recover_depth=2, n_rungs=3,
                                backoff_s=0.0),
        max_batch=8, buckets=(8,), metrics=m, tracer=Tracer())
    srv.submit_many(tiny["queries"])          # 48 deep ≫ degrade_depth
    srv.drain()
    snap = snapshot(m)
    downs = [k for k in snap["counters"]
             if k.startswith("serve_degradation_transitions_total")
             and 'direction="down"' in k]
    assert downs and sum(snap["counters"][k] for k in downs) > 0
    evts = [e for e in snap["events"]
            if e["name"] == "serve_degradation_transition"]
    assert evts
    assert {"from_rung", "rung", "direction", "reason",
            "delta_bound"} <= set(evts[0])
    assert "serve_rung" in snap["gauges"]


# ---------------------------------------------------------------------------
# WAL / checkpoint timings.
# ---------------------------------------------------------------------------


def test_journal_wal_and_checkpoint_timed(tmp_path, tiny):
    from repro.core import BuildParams
    from repro.core.updates import JournaledLiveIndex, as_live, recover

    m = MetricsRegistry()
    live = as_live(tiny["graph"],
                   BuildParams(max_degree=12, beam_width=20, t=10, iters=1,
                               block=128))
    j = JournaledLiveIndex.create(live, str(tmp_path), metrics=m)
    rng = np.random.default_rng(3)
    j.insert(rng.normal(size=(2, 16)).astype(np.float32))
    j.insert(rng.normal(size=(2, 16)).astype(np.float32))
    j.checkpoint()
    snap = snapshot(m)
    assert snap["histograms"]["wal_append_seconds"]["count"] == 2
    assert snap["histograms"]["wal_fsync_seconds"]["count"] > 0
    assert snap["counters"]['wal_records_total{op="insert"}'] == 2
    assert snap["histograms"]["checkpoint_save_seconds"]["count"] == 2

    m2 = MetricsRegistry()
    j2, info = recover(str(tmp_path), metrics=m2)
    assert j2.n_live == j.n_live
    assert info["elapsed_s"] >= 0
    assert snapshot(m2)["histograms"]["checkpoint_restore_seconds"]["count"] == 1


# ---------------------------------------------------------------------------
# Build events.
# ---------------------------------------------------------------------------


def test_build_emits_structured_phases(tiny):
    from repro.core import BuildParams, build_approx

    rng = np.random.default_rng(5)
    base = rng.normal(size=(200, 8)).astype(np.float32)
    m = MetricsRegistry()
    build_approx(base, BuildParams(max_degree=8, beam_width=16, t=8, iters=1,
                                   block=128), metrics=m)
    phases = [e["phase"] for e in m.events if e["name"] == "build_progress"]
    assert "bootstrap" in phases
    assert any(p.startswith("refine_iter") for p in phases)
    snap = snapshot(m)
    assert any(k.startswith("build_phase_seconds") for k in snap["histograms"])
    assert snap["counters"]["build_nodes_total"] > 0


# ---------------------------------------------------------------------------
# CLI: the acceptance snapshot.
# ---------------------------------------------------------------------------


def test_serve_cli_metrics_snapshot(capsys):
    from repro.launch.serve import main

    rc = main(["--n", "400", "--dim", "8", "--queries", "24", "--k", "5",
               "--beam", "16", "--max-degree", "8", "--metrics"])
    assert rc == 0
    outp = capsys.readouterr().out
    prom = outp.split("=== metrics (prometheus text) ===")[1] \
               .split("=== metrics (json) ===")[0]
    for family in ("serve_request_latency_seconds_bucket",
                   'serve_request_latency_seconds{quantile="0.5"}',
                   'serve_request_latency_seconds{quantile="0.99"}',
                   "serve_queue_wait_seconds_bucket",
                   "serve_degradation_transitions_total",
                   'shard_live{shard="0"}',
                   "wal_append_seconds_bucket", "wal_fsync_seconds_bucket",
                   "search_dist_comps_total", "search_hops_total"):
        assert family in prom, family
    snap = json.loads(outp.split("=== metrics (json) ===")[1].strip())
    assert snap["histograms"]["serve_request_latency_seconds"]["count"] == 24
    assert snap["counters"]["search_dist_comps_total"] > 0
    assert any(s["name"] == "serve.request" for s in snap["spans"])
