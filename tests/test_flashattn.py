"""Pallas flash-attention kernel vs the full-matrix oracle (interpret mode),
swept over causal/window/GQA/padding shapes, plus agreement with the
pure-jnp blockwise attention used by the LM models."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flashattn.ops import flash_attention
from repro.kernels.flashattn import ref

CASES = [
    # B, S, H, KV, hd, causal, window, bq, bk
    (2, 64, 4, 2, 32, True, None, 16, 16),
    (1, 100, 6, 3, 16, True, None, 32, 32),      # S not divisible by blocks
    (2, 128, 4, 4, 32, True, 32, 32, 32),        # sliding window
    (1, 64, 2, 1, 64, False, None, 16, 16),      # bidirectional
    (1, 48, 8, 2, 16, True, 16, 16, 16),         # window + GQA
]


@pytest.mark.parametrize("B,S,H,KV,hd,causal,window,bq,bk", CASES)
def test_flash_vs_oracle(B, S, H, KV, hd, causal, window, bq, bk):
    rng = np.random.default_rng(S + H)
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)).astype(np.float32))
    out = flash_attention(q, k, v, causal=causal, window=window, bq=bq, bk=bk)
    want = flash_attention(q, k, v, causal=causal, window=window, use_ref=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16_inputs():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 64, 4, 32)).astype(np.float32)).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)).astype(np.float32)).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)).astype(np.float32)).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, bq=16, bk=16)
    want = flash_attention(q, k, v, use_ref=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out).astype(np.float32),
                               np.asarray(want).astype(np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_matches_model_blockwise_attention():
    """The kernel and models/common.flash_attention compute the same math."""
    from repro.models.common import flash_attention as jnp_flash

    rng = np.random.default_rng(1)
    B, S, H, KV, hd = 2, 96, 6, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)).astype(np.float32))
    a = flash_attention(q, k, v, causal=True, bq=32, bk=32)
    b = jnp_flash(q, k, v, causal=True, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
