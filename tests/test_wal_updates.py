"""Crash-safe streaming updates: WAL commit protocol, crash-point sweep,
recovery bit-identity, and consolidation under fault injection.

The contract under test: a mutation is visible after recovery iff its WAL
record was *committed* (manifest written) before the crash — crashes at
``before_journal`` / ``torn_journal`` recover to the state WITHOUT the op,
crashes at ``after_journal`` / ``mid_splice`` recover to the state WITH it
(even though the in-memory index died half-mutated) — and recovery is
bit-for-bit identical to an uninterrupted run of the same committed op
sequence, certified by the graph-invariant auditor.

Marked ``faults``: CI runs this module under a pytest-timeout ceiling and
sweeps ``REPRO_FAULT_SEED`` (the ``fault_seed`` fixture) across a matrix.
"""

import numpy as np
import pytest

from repro.core.build_approx import BuildParams, build_approx
from repro.core import updates as U
from repro.core.updates import (
    JournaledLiveIndex,
    WalCorruptError,
    recover,
    wal_read,
    wal_seqs,
)
from repro.core.verify import audit_live
from repro.testing import SimulatedCrash, crash_at, torn_wal_record

pytestmark = pytest.mark.faults

BP = BuildParams(max_degree=10, beam_width=20, t=10, iters=1, block=128)
CRASH_POINTS = ("before_journal", "torn_journal", "after_journal",
                "mid_splice")


@pytest.fixture(scope="module")
def base_live():
    rng = np.random.default_rng(3)
    X = rng.standard_normal((220, 12)).astype(np.float32)
    return U.as_live(build_approx(X, BP), BP)


def _batch(seed, m=12, d=12):
    return np.random.default_rng(seed).standard_normal((m, d)) \
        .astype(np.float32)


def _state(live):
    g = live.graph
    return (np.asarray(g.vectors), np.asarray(g.neighbors),
            int(np.asarray(g.medoid)), live.tombstones.copy())


def _assert_bit_identical(a, b):
    va, na, ma, ta = _state(a)
    vb, nb, mb, tb = _state(b)
    np.testing.assert_array_equal(va, vb)
    np.testing.assert_array_equal(na, nb)
    assert ma == mb
    np.testing.assert_array_equal(ta, tb)


# ---------------------------------------------------------------------------
# Round trips without crashes.
# ---------------------------------------------------------------------------


def test_recover_bit_identical_after_clean_run(base_live, tmp_path,
                                               fault_seed):
    j = JournaledLiveIndex.create(base_live, str(tmp_path))
    j.insert(_batch(fault_seed))
    j.delete([1, 4, 9])
    j.insert(_batch(fault_seed + 1))
    j2, info = recover(str(tmp_path))
    assert info["replayed"] == 3 and info["torn_seq"] is None
    assert j2.seq == j.seq == 3
    _assert_bit_identical(j.live, j2.live)
    assert audit_live(j2.live).ok


def test_checkpoint_bounds_replay_and_truncates_wal(base_live, tmp_path,
                                                    fault_seed):
    j = JournaledLiveIndex.create(base_live, str(tmp_path),
                                  keep_checkpoints=1)
    j.insert(_batch(fault_seed))
    j.delete([0, 2])
    j.checkpoint()
    # records covered by the only retained checkpoint must be gone
    assert wal_seqs(j.wal_dir) == []
    j.insert(_batch(fault_seed + 2))
    j2, info = recover(str(tmp_path))
    assert info["checkpoint_step"] == 2 and info["replayed"] == 1
    _assert_bit_identical(j.live, j2.live)
    assert audit_live(j2.live).ok


# ---------------------------------------------------------------------------
# Crash-point sweep: every protocol point, both outcome classes.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("point", CRASH_POINTS)
@pytest.mark.parametrize("op", ["insert", "delete"])
def test_crash_point_sweep(base_live, tmp_path, fault_seed, point, op):
    """Kill the process at ``point`` during op #2 and recover.  The WAL
    semantics decide whether op #2 survives: committed (manifest on disk —
    ``after_journal`` / ``mid_splice``) means replayed, uncommitted
    (``before_journal`` / ``torn_journal``) means it never happened."""
    if op == "delete" and point == "mid_splice":
        pytest.skip("mid_splice is an insert-path fault point")
    d = str(tmp_path)
    j = JournaledLiveIndex.create(base_live, d)
    j.insert(_batch(fault_seed))         # op #1, committed
    pre_crash = j.live                   # state without op #2

    # oracle: the same op applied on an uninterrupted copy
    if op == "insert":
        payload = _batch(fault_seed + 7)
        oracle = U.insert(pre_crash, payload)
    else:
        payload = [3, 5]
        oracle = U.delete(pre_crash, payload)

    j.fault_hook = crash_at(point)
    with pytest.raises(SimulatedCrash):
        (j.insert if op == "insert" else j.delete)(payload)
    del j                                # the process is dead; only disk survives

    j2, info = recover(d)
    committed = point in ("after_journal", "mid_splice")
    if committed:
        assert info["replayed"] == 2
        assert j2.seq == 2
        _assert_bit_identical(j2.live, oracle)
    else:
        assert info["replayed"] == 1
        assert j2.seq == 1
        _assert_bit_identical(j2.live, pre_crash)
    if point == "torn_journal":          # payload without manifest on disk
        assert info["torn_seq"] == 2
        with pytest.raises(WalCorruptError):
            wal_read(j2.wal_dir, 2)
    rep = audit_live(j2.live)
    assert rep.ok, rep.summary()

    # the recovered journal must accept new mutations and stay recoverable
    j2.insert(_batch(fault_seed + 13))
    j3, _ = recover(d)
    _assert_bit_identical(j2.live, j3.live)


@pytest.mark.parametrize("mode", ["truncate", "checksum"])
def test_torn_record_detected_post_hoc(base_live, tmp_path, fault_seed,
                                       mode):
    """A record torn *after* commit (disk corruption) must stop replay at
    the preceding op, not crash recovery or replay garbage."""
    d = str(tmp_path)
    j = JournaledLiveIndex.create(base_live, d)
    j.insert(_batch(fault_seed))
    after_one = j.live
    j.delete([2, 6])
    torn_wal_record(j.wal_dir, 2, mode=mode)
    j2, info = recover(d)
    assert info["replayed"] == 1 and info["torn_seq"] == 2
    _assert_bit_identical(j2.live, after_one)
    assert audit_live(j2.live).ok


# ---------------------------------------------------------------------------
# Consolidation under fault injection (satellite).
# ---------------------------------------------------------------------------


def test_consolidate_frac_crossing_mid_stream(base_live, tmp_path,
                                              fault_seed):
    """Deletes that push the tombstone fraction past ``consolidate_frac``
    mid-stream must auto-consolidate, journal the consolidate as its own
    record, and leave a recoverable, audit-clean index."""
    d = str(tmp_path)
    j = JournaledLiveIndex.create(base_live, d, consolidate_frac=0.15)
    n = j.live.graph.n
    rng = np.random.default_rng(fault_seed)
    ids = rng.choice(n, size=int(0.2 * n), replace=False)
    for chunk in np.array_split(ids, 4):
        j.delete(chunk)
        rep = audit_live(j.live)
        assert rep.ok, rep.summary()
    ops = [wal_read(j.wal_dir, s)[0] for s in wal_seqs(j.wal_dir)]
    assert "consolidate" in ops          # journaled as its own record
    assert j.live.frac_deleted <= 0.15
    j2, info = recover(d)
    assert info["replayed"] == len(ops)
    _assert_bit_identical(j.live, j2.live)
    assert audit_live(j2.live).ok


def test_crash_during_auto_consolidate(base_live, tmp_path, fault_seed):
    """The auto-consolidate is a *separate* record: crashing before its
    journal commit recovers the deletes but not the consolidate (replay
    applies pure records, it never re-derives triggers)."""
    d = str(tmp_path)
    j = JournaledLiveIndex.create(base_live, d, consolidate_frac=0.1)
    n = j.live.graph.n
    ids = np.random.default_rng(fault_seed).choice(
        n, size=int(0.15 * n), replace=False)
    # visit 0 of before_journal is the delete itself; visit 1 the consolidate
    j.fault_hook = crash_at("before_journal", on_visit=1)
    with pytest.raises(SimulatedCrash):
        j.delete(ids)
    del j
    j2, info = recover(d)
    assert info["replayed"] == 1
    assert [wal_read(j2.wal_dir, s)[0] for s in wal_seqs(j2.wal_dir)] \
        == ["delete"]
    assert j2.live.frac_deleted > 0.1    # deletes survived, consolidate didn't
    assert audit_live(j2.live).ok
    # the recovered journal consolidates on its next trigger as usual
    j2.fault_hook = None
    j2.delete([int(np.where(~j2.live.tombstones)[0][0])])
    assert j2.live.frac_deleted <= 0.1
    assert audit_live(j2.live).ok


# ---------------------------------------------------------------------------
# WAL byte-threshold checkpointing + compressed payloads (satellite).
# ---------------------------------------------------------------------------


def test_byte_threshold_checkpoints_every_op(base_live, tmp_path, fault_seed):
    """``checkpoint_every_bytes=1``: every mutation crosses the threshold,
    so each op is immediately folded into a snapshot — with one retained
    checkpoint the WAL stays empty and recovery replays nothing."""
    from repro.obs import MetricsRegistry, snapshot

    m = MetricsRegistry()
    j = JournaledLiveIndex.create(base_live, str(tmp_path),
                                  checkpoint_every_bytes=1,
                                  keep_checkpoints=1, metrics=m)
    j.insert(_batch(fault_seed))
    j.delete([1, 2])
    j.insert(_batch(fault_seed + 1))
    assert wal_seqs(j.wal_dir) == []
    assert j._wal_bytes == 0
    snap = snapshot(m)
    assert snap["counters"]["wal_auto_checkpoint_total"] == 3
    assert snap["gauges"]["wal_bytes_since_checkpoint"] == 0
    j2, info = recover(str(tmp_path))
    assert info["replayed"] == 0           # snapshots carry all the state
    assert j2.checkpoint_every_bytes == 1  # knob round-trips through meta
    _assert_bit_identical(j.live, j2.live)
    assert audit_live(j2.live).ok


def test_byte_accumulator_tracks_disk_and_survives_recovery(
        base_live, tmp_path, fault_seed):
    """The byte accumulator is the on-disk footprint of records since the
    last checkpoint: it grows per record, ``recover()`` recomputes the
    identical value from disk, and the first record that crosses the
    threshold triggers exactly one auto-checkpoint."""
    d = str(tmp_path)
    j = JournaledLiveIndex.create(base_live, d,
                                  checkpoint_every_bytes=1 << 30)
    j.insert(_batch(fault_seed))
    b1 = j._wal_bytes
    assert b1 == U._record_bytes(j.wal_dir, 1) > 0
    j.delete([3])
    assert j._wal_bytes > b1

    j2, _ = recover(d)
    assert j2._wal_bytes == j._wal_bytes   # recomputed, not persisted

    j2.checkpoint_every_bytes = j2._wal_bytes + 1   # next record crosses it
    j2.insert(_batch(fault_seed + 1))
    assert j2._wal_bytes == 0              # auto-checkpoint reset
    j3, info = recover(d)
    assert info["replayed"] == 0
    _assert_bit_identical(j2.live, j3.live)
    assert audit_live(j3.live).ok


def test_compressed_wal_recovers_bit_identically(base_live, tmp_path,
                                                 fault_seed):
    """``compress=True`` journals payloads with ``savez_compressed``: same
    committed ops → bit-identical state vs a plain journal, smaller records
    on compressible data, and the flag round-trips through recovery (the
    manifest checksums arrays, not files, so readers are format-blind)."""
    dp, dc = str(tmp_path / "plain"), str(tmp_path / "comp")
    jp = JournaledLiveIndex.create(base_live, dp)
    jc = JournaledLiveIndex.create(base_live, dc, compress=True)
    batch = np.tile(_batch(fault_seed, m=1), (24, 1))   # compressible
    for j in (jp, jc):
        j.insert(batch)
        j.delete([5, 6])
    _assert_bit_identical(jp.live, jc.live)
    assert U._record_bytes(jc.wal_dir, 1) < U._record_bytes(jp.wal_dir, 1)

    jc2, info = recover(dc)
    assert jc2.compress is True
    assert info["replayed"] == 2 and info["torn_seq"] is None
    _assert_bit_identical(jc2.live, jp.live)
    # duplicate-row inserts legitimately leave unreachable duplicates, so
    # "audit-clean" is not the claim here — identical audit outcome is
    assert (audit_live(jc2.live).violations
            == audit_live(jp.live).violations)
    # the recovered journal keeps appending compressed and stays recoverable
    jc2.insert(_batch(fault_seed + 1))
    jp.insert(_batch(fault_seed + 1))
    _assert_bit_identical(recover(dc)[0].live, jp.live)


def test_delete_then_reinsert_same_row(base_live, tmp_path, fault_seed):
    """Deleting a row and re-inserting its exact vector must serve the new
    copy (distance 0), stay consistent through consolidate, and recover
    bit-identically."""
    d = str(tmp_path)
    j = JournaledLiveIndex.create(base_live, d, consolidate_frac=0.9)
    victim = 17
    v = np.asarray(j.live.graph.vectors)[victim].copy()
    j.delete([victim])
    res = j.search(v[None], k=1)
    ids = np.asarray(res.ids)
    assert ids[0, 0] != victim           # tombstone filtered from results
    j.insert(v[None])
    res = j.search(v[None], k=1)
    assert float(np.asarray(res.dists)[0, 0]) <= 1e-6
    assert not j.live.tombstones[int(np.asarray(res.ids)[0, 0])]
    j.consolidate()                      # splices the dead original out
    rep = audit_live(j.live)
    assert rep.ok, rep.summary()
    res = j.search(v[None], k=1)
    assert float(np.asarray(res.dists)[0, 0]) <= 1e-6
    j2, _ = recover(d)
    _assert_bit_identical(j.live, j2.live)
    assert audit_live(j2.live).ok
