"""Per-architecture smoke tests (deliverable f): instantiate the REDUCED
config of each assigned arch, run one forward/train step on CPU, assert
output shapes + finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import gnn as gnn_mod
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.optim import OptConfig
from repro.train import TrainState, make_train_step

LM_ARCHS = ["moonshot-v1-16b-a3b", "llama4-maverick-400b-a17b",
            "internlm2-20b", "phi3-mini-3.8b", "smollm-135m"]


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke(arch_id):
    arch = get_arch(arch_id)
    cfg: tf.LMConfig = arch.smoke_cfg
    params = tf.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)

    logits, aux = tf.forward(cfg, params, toks)
    assert logits.shape == (2, 12, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    opt = OptConfig(lr=1e-3, total_steps=10)
    step = jax.jit(make_train_step(
        lambda p, b: tf.loss_fn(cfg, p, b["tokens"], b["targets"]), opt))
    state = TrainState.create(params, opt)
    state, m = step(state, {"tokens": toks, "targets": toks})
    assert np.isfinite(float(m["loss"]))

    # decode one token with a cache
    cache = tf.init_cache(cfg, 2, 16, dtype=jnp.float32)
    lg, cache = tf.decode_step(cfg, state.params, cache, toks[:, 0])
    assert lg.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg)))
    assert int(cache["pos"][0]) == 1


def test_lm_full_configs_param_counts():
    """The FULL configs must match their nameplate scales (exercised only
    abstractly — eval_shape, no allocation)."""
    expect = {
        "moonshot-v1-16b-a3b": (20e9, 40e9),
        "llama4-maverick-400b-a17b": (350e9, 450e9),
        "internlm2-20b": (15e9, 25e9),
        "phi3-mini-3.8b": (3e9, 5e9),
        "smollm-135m": (0.1e9, 0.25e9),
    }
    for arch_id, (lo, hi) in expect.items():
        cfg = get_arch(arch_id).model_cfg
        shapes = jax.eval_shape(lambda c=cfg: tf.init(c, jax.random.PRNGKey(0)))
        total = sum(np.prod(l.shape) for l in jax.tree.leaves(shapes))
        assert lo < total < hi, (arch_id, total)
        assert abs(total - cfg.param_count()) / total < 0.02


def test_gat_smoke():
    arch = get_arch("gat-cora")
    cfg = arch.smoke_cfg
    params = gnn_mod.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    N, E = 64, 256
    x = jnp.asarray(rng.normal(size=(N, cfg.d_in)).astype(np.float32))
    src = jnp.asarray(rng.integers(0, N, E).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, N, E).astype(np.int32))
    logits = gnn_mod.forward(cfg, params, x, src, dst)
    assert logits.shape == (N, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))

    labels = jnp.asarray(rng.integers(0, cfg.n_classes, N).astype(np.int32))
    opt = OptConfig(lr=1e-2, total_steps=10)
    step = jax.jit(make_train_step(
        lambda p, b: gnn_mod.loss_fn(cfg, p, b["x"], b["src"], b["dst"],
                                     b["labels"], b["mask"]), opt))
    state = TrainState.create(params, opt)
    state, m = step(state, {"x": x, "src": src, "dst": dst, "labels": labels,
                            "mask": jnp.ones(N, bool)})
    assert np.isfinite(float(m["loss"]))


RECSYS = {
    "fm": (rs.fm_init, rs.fm_loss),
    "dcn-v2": (rs.dcn_init, rs.dcn_loss),
    "dien": (rs.dien_init, rs.dien_loss),
    "mind": (rs.mind_init, rs.mind_loss),
}


@pytest.mark.parametrize("arch_id", list(RECSYS))
def test_recsys_smoke(arch_id):
    from repro.data import recsys_ctr_batch, recsys_seq_batch

    arch = get_arch(arch_id)
    cfg = arch.smoke_cfg
    init_fn, loss_fn = RECSYS[arch_id]
    params = init_fn(cfg, jax.random.PRNGKey(0))
    B = 16
    if arch_id in ("fm", "dcn-v2"):
        raw = recsys_ctr_batch(B, step=0, n_sparse=cfg.n_sparse, rows=cfg.rows)
        batch = {"sparse_ids": jnp.asarray(raw["sparse_ids"]),
                 "label": jnp.asarray(raw["label"])}
        if arch_id == "dcn-v2":
            batch["dense"] = jnp.asarray(raw["dense"])
    else:
        raw = recsys_seq_batch(B, step=0, n_items=cfg.n_items,
                               seq_len=cfg.seq_len,
                               n_neg=getattr(cfg, "n_neg", 4))
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        if arch_id == "dien":
            batch["hist_cats"] = jnp.asarray(raw["hist_items"] % cfg.n_cats)
            batch["target_cat"] = jnp.asarray(raw["target_item"] % cfg.n_cats)

    opt = OptConfig(lr=1e-3, total_steps=10)
    step = jax.jit(make_train_step(lambda p, b: loss_fn(cfg, p, b), opt))
    state = TrainState.create(params, opt)
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))


def test_mind_retrieval_smoke():
    arch = get_arch("mind")
    cfg = arch.smoke_cfg
    params = rs.mind_init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    hist = jnp.asarray(rng.integers(0, cfg.n_items, (2, cfg.seq_len)).astype(np.int32))
    mask = jnp.ones((2, cfg.seq_len), bool)
    score, ids = rs.mind_retrieval(cfg, params, hist, mask,
                                   jnp.arange(512, dtype=jnp.int32), k=20)
    assert score.shape == (2, 20) and ids.shape == (2, 20)
    assert bool(jnp.all(jnp.isfinite(score)))
    # scores sorted descending
    assert (np.diff(np.asarray(score), axis=1) <= 1e-6).all()


def test_ann_smoke_config():
    """The paper's own (sift1m) smoke config builds + serves end to end."""
    from repro.core import build_emqg, error_bounded_probing_search
    from repro.data import clustered_vectors

    arch = get_arch("sift1m")
    sc = arch.smoke_cfg
    X = clustered_vectors(sc["n"], sc["dim"], 32, seed=0)
    idx = build_emqg(X, sc["build"])
    res = error_bounded_probing_search(
        idx, jnp.asarray(X[:16] + 0.01), k=sc["search"].k,
        alpha=sc["search"].alpha, l_max=sc["search"].l_max)
    assert res.ids.shape == (16, sc["search"].k)
    assert bool(jnp.all(jnp.isfinite(res.dists)))
