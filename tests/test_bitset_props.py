"""Property tests for the packed visited bitset (``core/bitset.py``).

The bitset is the beam engine's dedup primitive and — since the faithful
Alg.-3 prune — also supports clearing (pruned-unexpanded candidates must be
able to re-enter the search).  Hypothesis drives randomized set/clear/test
round-trips against a plain Python-set model; deterministic versions of the
same invariants run even when hypothesis is absent (the compat shim turns
``@given`` tests into skips, and the clear op is load-bearing for
``faithful_prune`` so it must be covered unconditionally).

CI selects the ``ci`` hypothesis profile (conftest): derandomized, bounded
examples.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core.bitset import (
    bitset_clear,
    bitset_make,
    bitset_set,
    bitset_test,
    bitset_words,
    unique_per_row,
)

N = 200     # id space for the property tests (spans multiple uint32 words)


def _row(ids):
    """int32[1, K] row from a python list (pad-free)."""
    return jnp.asarray(np.asarray(ids, np.int32)[None, :])


# ---------------------------------------------------------------------------
# Deterministic invariants (always run).
# ---------------------------------------------------------------------------

def test_clear_inverts_set():
    ids = _row([0, 31, 32, 63, 64, 199])
    bits0 = bitset_make(1, N)
    bits1 = bitset_set(bits0, ids)
    assert np.asarray(bitset_test(bits1, ids)).all()
    bits2 = bitset_clear(bits1, ids)
    np.testing.assert_array_equal(np.asarray(bits2), np.asarray(bits0))
    assert not np.asarray(bitset_test(bits2, ids)).any()


def test_clear_subset_leaves_rest():
    bits = bitset_set(bitset_make(1, N), _row([3, 5, 7, 64, 65]))
    bits = bitset_clear(bits, _row([5, 64, -1]))
    got = np.asarray(bitset_test(bits, _row([3, 5, 7, 64, 65])))[0]
    assert got.tolist() == [True, False, True, False, True]


def test_clear_unset_bits_is_noop():
    bits = bitset_set(bitset_make(1, N), _row([10, 20]))
    bits2 = bitset_clear(bits, _row([11, 21, 199]))
    np.testing.assert_array_equal(np.asarray(bits2), np.asarray(bits))


def test_clear_invalid_ids_noop():
    bits = bitset_set(bitset_make(1, N), _row([42]))
    bits2 = bitset_clear(bits, _row([-1, -7]))
    np.testing.assert_array_equal(np.asarray(bits2), np.asarray(bits))


def test_clear_per_row_independent():
    ids = jnp.asarray([[1, 33], [1, 33]], jnp.int32)
    bits = bitset_set(bitset_make(2, N), ids)
    bits = bitset_clear(bits, jnp.asarray([[1, -1], [-1, 33]], jnp.int32))
    got = np.asarray(bitset_test(bits, ids))
    assert got.tolist() == [[False, True], [True, False]]


def test_words_cover_id_space():
    for n in (1, 31, 32, 33, 200, 1024):
        assert bitset_words(n) * 32 >= n
        assert (bitset_words(n) - 1) * 32 < n


# ---------------------------------------------------------------------------
# Hypothesis properties (CI: derandomized profile; local: skip w/o dep).
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(ids=st.lists(st.integers(0, N - 1), min_size=1, max_size=40,
                    unique=True))
def test_set_test_roundtrip_vs_model(ids):
    """Members test True, non-members False — exactly the python-set model."""
    bits = bitset_set(bitset_make(1, N), _row(ids))
    model = set(ids)
    probe = list(range(0, N, 3)) + ids
    got = np.asarray(bitset_test(bits, _row(probe)))[0]
    assert got.tolist() == [v in model for v in probe]


@settings(max_examples=50, deadline=None)
@given(ids=st.lists(st.integers(0, N - 1), min_size=1, max_size=40,
                    unique=True),
       drop=st.sets(st.integers(0, N - 1), max_size=20))
def test_set_clear_vs_model(ids, drop):
    """set(A) then clear(B) ⇔ membership A \\ B (clearing absent ids is a
    no-op, mirroring a prune of a never-seen candidate)."""
    bits = bitset_set(bitset_make(1, N), _row(ids))
    bits = bitset_clear(bits, _row(sorted(drop)))
    model = set(ids) - drop
    probe = list(range(N))
    got = np.asarray(bitset_test(bits, _row(probe)))[0]
    assert got.tolist() == [v in model for v in probe]


@settings(max_examples=50, deadline=None)
@given(ids=st.lists(st.integers(-1, N - 1), min_size=1, max_size=60))
def test_unique_per_row_vs_np_unique(ids):
    """Valid output entries == np.unique of the valid inputs, ascending,
    with the tail padded INVALID."""
    arr = _row(ids)
    out = np.asarray(unique_per_row(arr, arr >= 0))[0]
    valid = out[out >= 0]
    expect = np.unique(np.asarray([v for v in ids if v >= 0], np.int32))
    np.testing.assert_array_equal(valid, expect)
    if valid.size:
        assert (np.diff(valid) > 0).all()
    assert (out[valid.size:] == -1).all()


@settings(max_examples=30, deadline=None)
@given(ids=st.lists(st.integers(0, N - 1), min_size=1, max_size=30,
                    unique=True))
def test_clear_is_involution_boundary(ids):
    """set→clear→set→clear lands back at empty: add/drop cycles cannot
    leak bits (the faithful-prune loop does exactly this per hop)."""
    empty = bitset_make(1, N)
    row = _row(ids)
    bits = bitset_clear(bitset_set(empty, row), row)
    bits = bitset_clear(bitset_set(bits, row), row)
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(empty))


def test_hypothesis_status_reported():
    """Make the optional-dependency state visible in the test report."""
    assert HAVE_HYPOTHESIS in (True, False)
