"""Beyond-paper production features: streaming updates, filtered search,
MIPS retrieval."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BuildParams, build_approx, error_bounded_search
from repro.core.distances import brute_force_knn
from repro.core.filtered import filtered_search
from repro.core.mips import build_mips, ip_from_l2, mips_search
from repro.core.updates import as_live, consolidate, delete, insert, search_live
from repro.data import clustered_vectors

from conftest import recall_at_k

BP = BuildParams(max_degree=20, beam_width=48, t=24, iters=2, block=512)


@pytest.fixture(scope="module")
def live_setup():
    base = clustered_vectors(1200, 32, 24, seed=8, scale=0.6)
    extra = clustered_vectors(200, 32, 24, seed=9, scale=0.6)
    queries = clustered_vectors(32, 32, 24, seed=10, scale=0.6)
    return base, extra, queries


def test_insert_matches_rebuild_quality(live_setup):
    base, extra, queries = live_setup
    full = np.concatenate([base, extra])
    gt_d, gt_i = brute_force_knn(queries, full, 10)

    live = as_live(build_approx(base, BP), BP)
    live = insert(live, extra)
    assert live.graph.n == 1400
    res = search_live(live, queries, k=10, alpha=1.6, l_max=128)
    rec_inc = recall_at_k(res.ids, gt_i, 10)

    rebuilt = build_approx(full, BP)
    res_rb = error_bounded_search(rebuilt, jnp.asarray(queries), k=10,
                                  alpha=1.6, l_max=128)
    rec_rb = recall_at_k(res_rb.ids, gt_i, 10)
    assert rec_inc > rec_rb - 0.1, (rec_inc, rec_rb)
    assert rec_inc > 0.7


def test_delete_excludes_and_consolidate_compacts(live_setup):
    base, _, queries = live_setup
    live = as_live(build_approx(base, BP), BP)
    dead = np.arange(0, 300)
    live = delete(live, dead)
    res = search_live(live, queries, k=10, alpha=1.6, l_max=128)
    ids = np.asarray(res.ids)
    assert not np.isin(ids[ids >= 0], dead).any()

    # ground truth over survivors
    alive_mask = np.ones(1200, bool)
    alive_mask[dead] = False
    gt_d, gt_i_local = brute_force_knn(queries, base[alive_mask], 10)
    # map live ids to survivor-local ids for recall
    remap = -np.ones(1200, np.int64)
    remap[np.where(alive_mask)[0]] = np.arange(alive_mask.sum())
    ids_local = np.where(ids >= 0, remap[np.maximum(ids, 0)], -1)
    rec = np.mean([len(set(ids_local[i].tolist()) & set(gt_i_local[i].tolist())) / 10
                   for i in range(len(queries))])
    assert rec > 0.6

    comp = consolidate(live)
    assert comp.graph.n == 900
    assert comp.frac_deleted == 0.0
    res2 = error_bounded_search(comp.graph, jnp.asarray(queries), k=10,
                                alpha=1.6, l_max=128)
    ids2 = np.asarray(res2.ids)
    rec2 = np.mean([len(set(ids2[i].tolist()) & set(gt_i_local[i].tolist())) / 10
                    for i in range(len(queries))])
    assert rec2 > 0.6


def test_filtered_search_respects_mask(live_setup):
    base, _, queries = live_setup
    g = build_approx(base, BP)
    rng = np.random.default_rng(0)
    mask = rng.random(1200) < 0.3                     # 30% selectivity
    res = filtered_search(g, queries, mask, k=5, alpha=1.6, l_max=192)
    ids = np.asarray(res.ids)
    valid = ids >= 0
    assert valid.any()
    assert mask[ids[valid]].all()
    # recall against filtered brute force
    sub = np.where(mask)[0]
    gt_d, gt_loc = brute_force_knn(queries, base[sub], 5)
    gt_ids = sub[gt_loc]
    rec = np.mean([len(set(ids[i][ids[i] >= 0].tolist())
                       & set(gt_ids[i].tolist())) / 5
                   for i in range(len(queries))])
    assert rec > 0.55


def test_mips_matches_brute_force_ip(live_setup):
    base, _, queries = live_setup
    mips = build_mips(base, BP)
    res = mips_search(mips, queries, k=10, alpha=1.6, l_max=128)
    ids = np.asarray(res.ids)
    # brute-force inner-product top-10
    scores = queries @ base.T
    gt = np.argsort(-scores, axis=1)[:, :10]
    rec = np.mean([len(set(ids[i].tolist()) & set(gt[i].tolist())) / 10
                   for i in range(len(queries))])
    assert rec > 0.7
    # score recovery identity
    ip = ip_from_l2(queries, np.asarray(res.dists), mips.radius)
    want = np.take_along_axis(scores, ids, axis=1)
    np.testing.assert_allclose(ip, want, rtol=1e-3, atol=1e-2)
