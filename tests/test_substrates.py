"""Substrate tests: optimizer, checkpointing (incl. crash safety), data
determinism, samplers, serving."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_latest, save_checkpoint
from repro.checkpoint.manager import list_steps
from repro.data import (
    clustered_vectors,
    lm_batch,
    make_markov_lm,
    molecule_batch,
    recsys_ctr_batch,
    recsys_seq_batch,
    sbm_graph,
)
from repro.data.sampler import CSRGraph, fanout_sample
from repro.optim import OptConfig, adamw_init, adamw_update, cosine_schedule


def test_adamw_converges_quadratic():
    cfg = OptConfig(lr=0.05, weight_decay=0.0, total_steps=200,
                    warmup_steps=0, schedule="const")
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params, cfg)
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, state, m = adamw_update(g, state, params, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_clipping_and_schedule():
    cfg = OptConfig(lr=1.0, clip_norm=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(cfg, jnp.int32(0))) == 0.0
    assert float(cosine_schedule(cfg, jnp.int32(10))) == pytest.approx(1.0, rel=0.1)
    assert float(cosine_schedule(cfg, jnp.int32(100))) == pytest.approx(
        cfg.lr * cfg.min_lr_frac, rel=0.01)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params, cfg)
    _, _, m = adamw_update({"w": jnp.full(4, 100.0)}, state, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_adamw_bf16_state():
    cfg = OptConfig(lr=0.01, state_dtype=jnp.bfloat16, total_steps=10)
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = adamw_init(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    p2, s2, _ = adamw_update({"w": jnp.ones(4, jnp.bfloat16)}, state, params, cfg)
    assert p2["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(p2["w"].astype(jnp.float32))))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones(4, jnp.int32)},
            "lst": [jnp.zeros(2), jnp.full(3, 7.0)]}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t)
    step, restored = restore_latest(str(tmp_path), jax.tree.map(jnp.zeros_like, t))
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_and_torn_save(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        save_checkpoint(str(tmp_path), s, t, keep=2)
    assert list_steps(str(tmp_path)) == [3, 4]
    # simulate a torn save: .tmp dir + corrupt latest
    os.makedirs(tmp_path / "step_000000009.tmp")
    os.makedirs(tmp_path / "step_000000005")  # no manifest → invalid
    step, _ = restore_latest(str(tmp_path), t)
    assert step == 4  # falls back past the invalid one


def test_checkpoint_corrupt_arrays_fall_back(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    save_checkpoint(str(tmp_path), 2, t, keep=5)
    # corrupt step 2's array file
    with open(tmp_path / "step_000000002" / "arrays.npz", "wb") as f:
        f.write(b"garbage")
    step, restored = restore_latest(str(tmp_path), t)
    assert step == 1


def test_checkpoint_manager_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=2, keep=2, async_save=True)
    t = _tree()
    assert not mgr.maybe_save(1, t)
    assert mgr.maybe_save(2, t)
    mgr.wait()
    assert list_steps(str(tmp_path)) == [2]


# ---------------------------------------------------------------------------
# data determinism (fault-tolerant resume depends on it)
# ---------------------------------------------------------------------------

def test_lm_batch_deterministic_by_step():
    lm = make_markov_lm(128, branch=4, seed=0)
    a1, b1 = lm_batch(lm, 4, 16, step=5, seed=9)
    a2, b2 = lm_batch(lm, 4, 16, step=5, seed=9)
    a3, _ = lm_batch(lm, 4, 16, step=6, seed=9)
    np.testing.assert_array_equal(a1, a2)
    assert not np.array_equal(a1, a3)
    # every target is one of the chain's `branch` successors of its token
    succ = lm.succ[a1]                                  # [B, S, branch]
    assert (b1[..., None] == succ).any(-1).all()


def test_recsys_batches_deterministic():
    b1 = recsys_ctr_batch(8, step=3)
    b2 = recsys_ctr_batch(8, step=3)
    np.testing.assert_array_equal(b1["sparse_ids"], b2["sparse_ids"])
    s1 = recsys_seq_batch(8, step=3, n_items=1000)
    s2 = recsys_seq_batch(8, step=3, n_items=1000)
    np.testing.assert_array_equal(s1["hist_items"], s2["hist_items"])
    assert s1["hist_items"].max() < 1000


def test_sbm_graph_and_sampler():
    g = sbm_graph(500, 5, 16, seed=0)
    assert g["src"].shape == g["dst"].shape
    assert g["src"].max() < 500 and g["src"].min() >= 0
    csr = CSRGraph.from_edges(g["src"], g["dst"], 500)
    sub = fanout_sample(csr, g["x"], g["labels"], np.arange(16), (4, 3),
                        pad_nodes=300, pad_edges=400)
    src, dst = sub["src"], sub["dst"]
    valid = src >= 0
    n_sub = sub["n_sub_nodes"]
    assert (src[valid] < n_sub).all() and (dst[valid] < n_sub).all()
    assert sub["label_mask"][:16].all() and not sub["label_mask"][16:].any()
    # sampled subgraph edges exist in the original graph
    edge_set = set(zip(g["src"].tolist(), g["dst"].tolist()))
    # rebuild global ids: order maps local → global
    # (first 16 locals are the seeds)
    assert sub["x"].shape == (300, 16)


def test_clustered_vectors_shape_and_spread():
    X = clustered_vectors(500, 16, 10, seed=1)
    assert X.shape == (500, 16) and np.isfinite(X).all()
    assert X.std() > 0.5


def test_molecule_batch():
    b = molecule_batch(8, 10, 20, 16, 2, step=0)
    assert b["x"].shape == (80, 16)
    assert b["graph_ids"].max() == 7


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_ann_server_batching(small_corpus):
    from repro.core import BuildParams, SearchParams, build_approx
    from repro.serve import AnnServer

    g = build_approx(small_corpus["base"],
                     BuildParams(max_degree=16, beam_width=32, t=8, iters=1))
    srv = AnnServer(g, SearchParams(k=5, l0=8, l_max=32, adaptive=False,
                                    max_hops=256), max_batch=16,
                    buckets=(4, 16))
    srv.submit_many(small_corpus["queries"][:23])
    out = srv.drain()
    assert len(out) == 23
    assert srv.stats.n_batches == 2
    ids0, d0 = out[0]
    assert ids0.shape == (5,) and (np.diff(d0) >= -1e-5).all()
