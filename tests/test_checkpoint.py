"""Checkpoint integrity and recovery: per-array checksums, verify-on-restore,
walk-back past corrupt/mismatched/torn steps, and the async-save crash
window.  Corruption tests carry ``@pytest.mark.faults``."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    list_steps,
    restore_latest,
    save_checkpoint,
)
from repro.testing import flip_bits, make_torn_tmp, tamper_array, tear_checkpoint


def tree_a(offset=0.0):
    return {"w": jnp.arange(12.0).reshape(3, 4) + offset,
            "b": jnp.ones((4,)) * (1.0 + offset)}


TEMPLATE = {"w": jnp.zeros((3, 4)), "b": jnp.zeros((4,))}


def step_dir(d, step):
    return os.path.join(d, f"step_{step:09d}")


def test_roundtrip_with_checksums(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 100, tree_a())
    import json
    with open(os.path.join(step_dir(d, 100), "manifest.json")) as f:
        manifest = json.load(f)
    assert set(manifest["checksums"]) == {"w", "b"}
    step, restored = restore_latest(d, TEMPLATE)
    assert step == 100
    np.testing.assert_allclose(restored["w"], np.asarray(tree_a()["w"]))
    np.testing.assert_allclose(restored["b"], np.asarray(tree_a()["b"]))


@pytest.mark.faults
def test_checksum_mismatch_walks_back(tmp_path, caplog):
    """Silent data corruption (array changed, archive still readable, manifest
    intact) must be caught by checksum verification and demoted to the
    next-older step."""
    d = str(tmp_path)
    save_checkpoint(d, 100, tree_a())
    save_checkpoint(d, 200, tree_a(1.0))
    tamper_array(step_dir(d, 200))
    with caplog.at_level("WARNING", logger="repro.checkpoint"):
        step, restored = restore_latest(d, TEMPLATE)
    assert step == 100
    np.testing.assert_allclose(restored["w"], np.asarray(tree_a()["w"]))
    assert any("checksum mismatch" in r.message for r in caplog.records)
    # escape hatch: verification off restores the tampered newest step
    step_nv, _ = restore_latest(d, TEMPLATE, verify=False)
    assert step_nv == 200


@pytest.mark.faults
def test_bitflipped_npz_walks_back(tmp_path):
    """Raw bit flips in arrays.npz — whether they break the zip structure or
    the payload, restore must recover the older step, never raise."""
    d = str(tmp_path)
    save_checkpoint(d, 100, tree_a())
    save_checkpoint(d, 200, tree_a(1.0))
    flip_bits(os.path.join(step_dir(d, 200), "arrays.npz"), n_bits=16, seed=3)
    step, restored = restore_latest(d, TEMPLATE)
    assert step == 100
    np.testing.assert_allclose(restored["b"], np.asarray(tree_a()["b"]))


@pytest.mark.faults
def test_template_keyset_mismatch_walks_back(tmp_path, caplog):
    """A structurally incompatible checkpoint (e.g. from an older model
    revision) used to raise ValueError mid-walk; it must log and continue."""
    d = str(tmp_path)
    save_checkpoint(d, 100, tree_a())
    save_checkpoint(d, 200, {"other": jnp.zeros((2,))})
    with caplog.at_level("WARNING", logger="repro.checkpoint"):
        step, restored = restore_latest(d, TEMPLATE)
    assert step == 100
    assert any("mismatch" in r.message for r in caplog.records)


@pytest.mark.faults
def test_torn_manifest_never_listed(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 100, tree_a())
    save_checkpoint(d, 200, tree_a(1.0))
    tear_checkpoint(step_dir(d, 200))
    assert list_steps(d) == [100]
    step, _ = restore_latest(d, TEMPLATE)
    assert step == 100


def test_nothing_valid_returns_template(tmp_path):
    d = str(tmp_path)
    step, restored = restore_latest(d, TEMPLATE)
    assert step is None and restored is TEMPLATE
    save_checkpoint(d, 100, tree_a())
    tear_checkpoint(step_dir(d, 100))
    step, restored = restore_latest(d, TEMPLATE)
    assert step is None and restored is TEMPLATE


def test_pre_checksum_checkpoint_still_restores(tmp_path):
    """Back-compat: a manifest without a ``checksums`` entry (older format)
    restores without verification errors."""
    import json
    d = str(tmp_path)
    save_checkpoint(d, 100, tree_a())
    mpath = os.path.join(step_dir(d, 100), "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["checksums"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    step, _ = restore_latest(d, TEMPLATE)
    assert step == 100


@pytest.mark.faults
def test_async_crash_window_recovery(tmp_path):
    """CheckpointManager async path: a process killed between ``maybe_save``
    and ``wait`` leaves only ``.tmp`` junk.  The next save must prune it,
    and ``restore_latest`` must keep finding the previous valid step both
    before and after that save."""
    d = str(tmp_path)
    mgr = CheckpointManager(d, every=100, keep=3, async_save=True)
    assert mgr.maybe_save(100, tree_a())
    mgr.wait()
    # simulated crash mid-save of step 200: torn .tmp, no committed dir
    make_torn_tmp(d, 200)
    assert any(n.endswith(".tmp") for n in os.listdir(d))
    step, _ = restore_latest(d, TEMPLATE)
    assert step == 100                       # junk never considered
    assert mgr.maybe_save(300, tree_a(2.0))
    mgr.wait()
    assert not any(n.endswith(".tmp") for n in os.listdir(d))
    step, restored = restore_latest(d, TEMPLATE)
    assert step == 300
    np.testing.assert_allclose(restored["w"], np.asarray(tree_a(2.0)["w"]))


def test_manager_off_cycle_step_skips(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=100, async_save=False)
    assert not mgr.maybe_save(101, tree_a())
    assert list_steps(str(tmp_path)) == []


def test_keep_prunes_oldest(tmp_path):
    d = str(tmp_path)
    for s in (100, 200, 300, 400):
        save_checkpoint(d, s, tree_a(float(s)), keep=2)
    assert list_steps(d) == [300, 400]


@pytest.mark.faults
def test_all_recent_corrupt_walks_to_oldest(tmp_path):
    """Multiple consecutive corrupt steps: the walk continues until a valid
    one is found."""
    d = str(tmp_path)
    save_checkpoint(d, 100, tree_a())
    save_checkpoint(d, 200, tree_a(1.0))
    save_checkpoint(d, 300, tree_a(2.0))
    tamper_array(step_dir(d, 300))
    flip_bits(os.path.join(step_dir(d, 200), "arrays.npz"), n_bits=16, seed=5)
    step, restored = restore_latest(d, TEMPLATE)
    assert step == 100
    np.testing.assert_allclose(restored["w"], np.asarray(tree_a()["w"]))
