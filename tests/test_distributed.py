"""Multi-device tests (sharded index search, merge exactness, dry-run cell).

These spawn subprocesses because --xla_force_host_platform_device_count must
be set before jax initializes, and the main pytest process must keep seeing
a single device for the smoke tests."""

import subprocess
import sys

import pytest

_PREAMBLE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import sys; sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
"""


def _run(body: str, n_devices: int = 8, timeout: int = 560) -> str:
    code = _PREAMBLE.format(n=n_devices) + body
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, cwd="/root/repo")
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
    return proc.stdout


def test_sharded_search_matches_brute_force():
    out = _run("""
from repro.core import BuildParams, SearchParams
from repro.core.distributed import build_sharded, make_sharded_search
from repro.core.distances import brute_force_knn
rng = np.random.default_rng(0)
X = rng.normal(size=(1024, 24)).astype(np.float32)
Q = rng.normal(size=(16, 24)).astype(np.float32)
gt_d, gt_i = brute_force_knn(Q, X, 10)
mesh = jax.make_mesh((4, 2), ("data", "model"))
sidx = build_sharded(X, 4, BuildParams(max_degree=16, beam_width=48, t=16, iters=2, block=512))
params = SearchParams(k=10, l0=10, l_max=64, alpha=2.0, adaptive=True, max_hops=512)
for merge in ("all_gather", "ring"):
    run = make_sharded_search(mesh, shard_axes=("data",), query_axis=None, merge=merge)
    ids, dists = run(sidx, jnp.asarray(Q), params)
    ids = np.asarray(ids)
    rec = np.mean([len(set(ids[i].tolist()) & set(gt_i[i].tolist()))/10 for i in range(16)])
    print(merge, "recall", rec)
    assert rec > 0.9, (merge, rec)
    d = np.asarray(dists)
    assert (np.diff(d, axis=1) >= -1e-5).all()
print("OK")
""")
    assert "OK" in out


def test_merge_strategies_agree():
    out = _run("""
from repro.core import BuildParams, SearchParams
from repro.core.distributed import build_sharded, make_sharded_search
rng = np.random.default_rng(1)
X = rng.normal(size=(512, 16)).astype(np.float32)
Q = rng.normal(size=(8, 16)).astype(np.float32)
mesh = jax.make_mesh((4, 2), ("data", "model"))
sidx = build_sharded(X, 4, BuildParams(max_degree=12, beam_width=24, t=8, iters=1, block=512))
params = SearchParams(k=5, l0=8, l_max=32, adaptive=False, max_hops=256)
runs = {m: make_sharded_search(mesh, shard_axes=("data",), query_axis=None, merge=m)
        for m in ("all_gather", "ring")}
outs = {m: np.asarray(r(sidx, jnp.asarray(Q), params)[0]) for m, r in runs.items()}
assert (outs["all_gather"] == outs["ring"]).all()
print("OK")
""")
    assert "OK" in out


def test_quantized_sharded_search():
    out = _run("""
from repro.core import BuildParams, SearchParams
from repro.core.distributed import build_sharded, make_sharded_search
from repro.core.distances import brute_force_knn
rng = np.random.default_rng(2)
X = rng.normal(size=(1024, 32)).astype(np.float32)
Q = rng.normal(size=(8, 32)).astype(np.float32)
gt_d, gt_i = brute_force_knn(Q, X, 10)
mesh = jax.make_mesh((4, 2), ("data", "model"))
sidx = build_sharded(X, 4, BuildParams(max_degree=16, beam_width=48, t=16, iters=2,
                                       block=512, align_degree=True), quantized=True)
params = SearchParams(k=10, l0=10, l_max=64, alpha=1.5, adaptive=True, max_hops=512)
run = make_sharded_search(mesh, shard_axes=("data",), query_axis=None,
                          merge="all_gather", quantized=True)
ids, dists = run(sidx, jnp.asarray(Q), params)
ids = np.asarray(ids)
rec = np.mean([len(set(ids[i].tolist()) & set(gt_i[i].tolist()))/10 for i in range(8)])
print("quantized recall", rec)
assert rec > 0.8
print("OK")
""")
    assert "OK" in out


def test_pad_rows_never_leak_global_ids():
    """Regression: the last shard's pad rows (wrapped copies of its first
    row) used to get global ids ``lo+j >= n_total``.  With the query sitting
    exactly ON the pad-source row the pads tie it at distance 0, so pre-fix
    they reached the merged top-k.  Both device merges and the host
    reference must now mask pads out like dead-shard entries: every
    returned id is in [0, n_total), valid ids are unique per row, and the
    pad-source row itself (whose real copy competes in the same local
    top-k) is still returned."""
    out = _run("""
from repro.core import BuildParams, SearchParams
from repro.core.distributed import (build_sharded, make_sharded_search,
                                    host_reference_merge, ShardHealthRegistry)
rng = np.random.default_rng(5)
X = rng.normal(size=(509, 16)).astype(np.float32)   # 4 shards of 128: 3 pads
sidx = build_sharded(X, 4, BuildParams(max_degree=12, beam_width=24, t=8,
                                       iters=1, block=512))
assert np.asarray(sidx.sizes).tolist() == [128, 128, 128, 125]
mesh = jax.make_mesh((4, 2), ("data", "model"))
params = SearchParams(k=8, l0=16, l_max=32, adaptive=False, max_hops=256)
# queries ON and near the pad-source row (global id 384 = last shard row 0)
Q = np.concatenate([X[384:385], X[384:385] + 0.01 * rng.normal(size=(3, 16)).astype(np.float32)])
def check(ids):
    ids = np.asarray(ids)
    assert ids.max() < sidx.n_total, ids.max()
    for row in ids:
        valid = row[row >= 0]
        assert len(set(valid.tolist())) == len(valid), row
    assert (ids[0] == 384).any()      # the source row itself is returned
for merge in ("all_gather", "ring"):
    run = make_sharded_search(mesh, shard_axes=("data",), merge=merge)
    ids, dists = run(sidx, jnp.asarray(Q), params)
    check(ids)
ref_i, _ = host_reference_merge(sidx, ShardHealthRegistry(4), jnp.asarray(Q),
                                params)
check(ref_i)
print("OK")
""")
    assert "OK" in out


def test_query_axis_sharding():
    out = _run("""
from repro.core import BuildParams, SearchParams
from repro.core.distributed import build_sharded, make_sharded_search
rng = np.random.default_rng(3)
X = rng.normal(size=(512, 16)).astype(np.float32)
Q = rng.normal(size=(8, 16)).astype(np.float32)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
sidx = build_sharded(X, 2, BuildParams(max_degree=12, beam_width=24, t=8, iters=1, block=512))
params = SearchParams(k=5, l0=8, l_max=32, adaptive=False, max_hops=256)
run = make_sharded_search(mesh, shard_axes=("data",), query_axis=("pod", "model"))
ids, dists = run(sidx, jnp.asarray(Q), params)
assert ids.shape == (8, 5)
run2 = make_sharded_search(mesh, shard_axes=("data",), query_axis=None)
ids2, _ = run2(sidx, jnp.asarray(Q), params)
assert (np.asarray(ids) == np.asarray(ids2)).all()
print("OK")
""")
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_single_cell_small_devices():
    """The dry-run driver machinery works end-to-end (8 fake devices, tiny
    mesh) — the full 512-device run is exercised by benchmarks/dryrun."""
    out = _run("""
from repro.configs import get_arch
from repro.launch.steps import build_cell
from repro.launch.mesh import make_host_mesh
from repro.launch.dryrun import parse_collectives
mesh = jax.make_mesh((4, 2), ("data", "model"))
arch = get_arch("fm")
cell = build_cell(arch, arch.shapes["serve_p99"], mesh)
compiled = cell.lower().compile()
mem = compiled.memory_analysis()
cost = compiled.cost_analysis()
if isinstance(cost, list):  # jax 0.4.x returns [dict], newer jax a dict
    cost = cost[0]
coll = parse_collectives(compiled.as_text())
assert cost.get("flops", 0) > 0
print("OK", int(mem.temp_size_in_bytes), coll["total_operand_bytes"])
""")
    assert "OK" in out


# ---------------------------------------------------------------------------
# Shard-loss tolerance: masked merges, coverage accounting, replica failover.
# ---------------------------------------------------------------------------


@pytest.mark.faults
def test_dead_shard_masked_merge_matches_survivor_reference():
    """With 1 of S shards killed the response must carry coverage=(S-1)/S
    and the merged ids must exactly equal the reference merge over the
    surviving shards — for BOTH merge strategies — with no dead-shard id
    leaking through."""
    out = _run("""
import os
from repro.core import BuildParams, SearchParams
from repro.core.distributed import (build_sharded, FaultTolerantShardedSearch,
                                    host_reference_merge)
seed = int(os.environ.get("REPRO_FAULT_SEED", "0"))
rng = np.random.default_rng(seed)
X = rng.normal(size=(512, 16)).astype(np.float32)
Q = rng.normal(size=(8, 16)).astype(np.float32)
mesh = jax.make_mesh((4, 2), ("data", "model"))
sidx = build_sharded(X, 4, BuildParams(max_degree=12, beam_width=24, t=8,
                                       iters=1, block=512))
params = SearchParams(k=5, l0=8, l_max=32, adaptive=False, max_hops=256)
dead = int(rng.integers(0, 4))
offs = np.append(np.asarray(sidx.offsets), sidx.n_total)
for merge in ("all_gather", "ring"):
    fts = FaultTolerantShardedSearch(sidx, mesh, merge=merge)
    fts.registry.mark_dead(dead)
    r = fts(jnp.asarray(Q), params)
    assert abs(r.coverage - 3/4) < 1e-9, r.coverage
    assert r.live_shards == 3 and r.n_shards == 4
    assert r.max_missed == min(params.k, int(offs[dead+1] - offs[dead]))
    ids = np.asarray(r.ids)
    assert not (((ids >= offs[dead]) & (ids < offs[dead+1])).any())
    ref_i, ref_d = host_reference_merge(sidx, fts.registry, jnp.asarray(Q),
                                        params)
    assert (ids == ref_i).all(), (merge, ids[0], ref_i[0])
    np.testing.assert_allclose(np.asarray(r.dists), ref_d, rtol=1e-6)
print("OK")
""")
    assert "OK" in out


@pytest.mark.faults
def test_replica_failover_restores_full_coverage():
    """Losing a primary with a live replica must fail over (coverage stays
    1.0, identical results); losing both degrades coverage; reviving
    restores it."""
    out = _run("""
from repro.core import BuildParams, SearchParams
from repro.core.distributed import build_replicated, FaultTolerantShardedSearch
rng = np.random.default_rng(4)
X = rng.normal(size=(512, 16)).astype(np.float32)
Q = rng.normal(size=(8, 16)).astype(np.float32)
mesh = jax.make_mesh((8,), ("data",))
sidx = build_replicated(X, 4, 2, BuildParams(max_degree=12, beam_width=24,
                                             t=8, iters=1, block=512))
params = SearchParams(k=5, l0=8, l_max=32, adaptive=False, max_hops=256)
fts = FaultTolerantShardedSearch(sidx, mesh, n_replicas=2)
r0 = fts(jnp.asarray(Q), params)
assert r0.coverage == 1.0 and r0.failover == 0
fts.registry.mark_dead(1, replica=0)       # primary lost -> replica serves
r1 = fts(jnp.asarray(Q), params)
assert r1.coverage == 1.0 and r1.failover == 1 and r1.max_missed == 0
assert (np.asarray(r0.ids) == np.asarray(r1.ids)).all()
fts.registry.mark_dead(1, replica=1)       # replica lost too -> degrade
r2 = fts(jnp.asarray(Q), params)
assert abs(r2.coverage - 3/4) < 1e-9 and r2.max_missed == 5
fts.registry.mark_live(1, replica=0)       # recovery
r3 = fts(jnp.asarray(Q), params)
assert r3.coverage == 1.0 and r3.failover == 0
assert (np.asarray(r3.ids) == np.asarray(r0.ids)).all()
print("OK")
""")
    assert "OK" in out


@pytest.mark.faults
def test_sharded_resilient_server_degrades_explicitly():
    """The resilient server over a sharded index: shard death degrades
    coverage per-response (never silently), a merge-tier fault falls back
    to the other exact merge, and revival restores coverage=1.0."""
    out = _run("""
import os
from repro.core import BuildParams, SearchParams
from repro.serve import ResilienceConfig, ShardedResilientAnnServer
from repro.testing import FaultPlan, inject_search_faults
seed = int(os.environ.get("REPRO_FAULT_SEED", "0"))
rng = np.random.default_rng(seed)
X = rng.normal(size=(512, 16)).astype(np.float32)
Q = rng.normal(size=(12, 16)).astype(np.float32)
mesh = jax.make_mesh((4,), ("data",))
from repro.core.distributed import build_sharded
sidx = build_sharded(X, 4, BuildParams(max_degree=12, beam_width=24, t=8,
                                       iters=1, block=512))
params = SearchParams(k=5, l0=8, l_max=32, adaptive=False, max_hops=256,
                      beam_width=1)
srv = ShardedResilientAnnServer(sidx, params, mesh,
                                config=ResilienceConfig(backoff_s=0.0))
srv.submit_many(Q)
rs = srv.drain()
assert all(r.ok and r.coverage == 1.0 and r.max_missed == 0 for r in rs)
assert all(r.tier == "sharded/all_gather" for r in rs)

srv.kill_shard(2)                          # shard death: explicit degradation
srv.submit_many(Q)
rs = srv.drain()
assert all(r.ok and abs(r.coverage - 3/4) < 1e-9 and r.max_missed == 5
           for r in rs)

srv.revive_shard(2)                        # merge-time collective fault:
with inject_search_faults(                 # primary merge tier opens,
        srv, FaultPlan(fail_first=10**6,   # the other exact merge serves
                       match_backend="all_gather")) as inj:
    srv.submit_many(Q)
    rs = srv.drain()
assert inj.n_failed >= 1
assert all(r.ok and r.tier == "sharded/ring" and r.coverage == 1.0
           for r in rs)
print("OK")
""")
    assert "OK" in out


# ---------------------------------------------------------------------------
# Deadline-based health checking (host-side: the registry/checker are pure
# numpy with injectable clocks, so no device subprocess is needed).
# ---------------------------------------------------------------------------


@pytest.mark.faults
def test_deadline_checker_kills_stale_replica_only():
    from repro.core.distributed import (DeadlineHealthChecker,
                                        ShardHealthRegistry)
    from repro.obs import MetricsRegistry, snapshot

    t = {"now": 0.0}
    reg = ShardHealthRegistry(4, n_replicas=2, clock=lambda: t["now"])
    m = MetricsRegistry()
    hc = DeadlineHealthChecker(reg, deadline_s=5.0, metrics=m)
    assert hc.check() == []                   # everything fresh at t=0

    t["now"] = 3.0                            # all beat except (1, 1) …
    for s in range(4):
        for r in range(2):
            if (s, r) != (1, 1):
                reg.heartbeat(s, r)
    t["now"] = 7.0                            # (1,1) age 7 > 5; rest age 4
    assert hc.check() == [(1, 1)]
    assert reg.coverage() == 1.0              # replica 0 still covers shard 1
    assert hc.n_killed == 1

    snap = snapshot(m)
    assert snap["counters"]["shard_marked_dead_total"] == 1
    assert snap["gauges"]['shard_live{shard="1"}'] == 1.0
    # per-shard rollup gauge tracks the freshest LIVE replica's age …
    assert abs(snap["gauges"]['shard_heartbeat_age_seconds{shard="1"}']
               - 4.0) < 1e-9
    # … while the per-replica family reports every slot's raw age (the
    # stale replica's 7.0 is visible even though the rollup hides it)
    assert abs(snap["gauges"][
        'shard_replica_heartbeat_age_seconds{replica="1",shard="1"}']
        - 7.0) < 1e-9
    assert abs(snap["gauges"][
        'shard_replica_heartbeat_age_seconds{replica="0",shard="1"}']
        - 4.0) < 1e-9
    evts = [e for e in snap["events"] if e["name"] == "shard_deadline_expired"]
    assert len(evts) == 1
    assert evts[0]["shard"] == 1 and evts[0]["replica"] == 1
    assert evts[0]["age_s"] > 5.0

    t["now"] = 10.0                           # now every survivor is stale
    killed = hc.check()
    assert (1, 1) not in killed               # dead slots are not re-killed
    assert len(killed) == 7
    assert reg.coverage() == 0.0
    assert snapshot(m)["gauges"]["shard_coverage"] == 0.0


@pytest.mark.faults
def test_zombie_heartbeat_does_not_revive_dead_slot():
    from repro.core.distributed import (DeadlineHealthChecker,
                                        ShardHealthRegistry)

    t = {"now": 0.0}
    reg = ShardHealthRegistry(2, clock=lambda: t["now"])
    hc = DeadlineHealthChecker(reg, deadline_s=1.0)
    t["now"] = 2.0
    assert len(hc.check()) == 2
    reg.heartbeat(0)                          # zombie's late beat: no revival
    assert reg.dead_shards() == [0, 1]
    assert hc.check() == []
    reg.mark_live(0)                          # explicit revival refreshes beat
    assert reg.live_shards() == [0]
    assert hc.check() == []                   # … so it is not instantly re-killed

    with pytest.raises(ValueError):
        DeadlineHealthChecker(reg, deadline_s=0.0)


@pytest.mark.faults
def test_sharded_server_health_deadline_auto_marks_dead():
    """Integration: a ShardedResilientAnnServer with ``health_deadline_s``
    auto-kills a shard whose heartbeats stop, degrading coverage explicitly
    on the next drain — no operator kill_shard needed."""
    out = _run("""
from repro.core import BuildParams, SearchParams
from repro.core.distributed import build_sharded
from repro.obs import MetricsRegistry, snapshot
from repro.serve import ResilienceConfig, ShardedResilientAnnServer
rng = np.random.default_rng(0)
X = rng.normal(size=(512, 16)).astype(np.float32)
Q = rng.normal(size=(12, 16)).astype(np.float32)
mesh = jax.make_mesh((4,), ("data",))
sidx = build_sharded(X, 4, BuildParams(max_degree=12, beam_width=24, t=8,
                                       iters=1, block=512))
params = SearchParams(k=5, l0=8, l_max=32, adaptive=False, max_hops=256,
                      beam_width=1)
t = {"now": 0.0}
m = MetricsRegistry()
srv = ShardedResilientAnnServer(sidx, params, mesh,
                                config=ResilienceConfig(backoff_s=0.0),
                                clock=lambda: t["now"],
                                health_deadline_s=5.0, metrics=m)
srv.submit_many(Q)
rs = srv.drain()
assert all(r.ok and r.coverage == 1.0 for r in rs)

t["now"] = 4.0
for s in (0, 1, 3):
    srv.heartbeat(s)                 # shard 2 goes silent
t["now"] = 7.0                       # age(2) = 7 > 5; others 3 < 5
srv.submit_many(Q)
rs = srv.drain()                     # checker sweeps before dispatch
assert srv.health_checker.n_killed == 1
assert all(r.ok and abs(r.coverage - 3/4) < 1e-9 for r in rs)
snap = snapshot(m)
assert snap["counters"]["shard_marked_dead_total"] == 1
assert snap["gauges"]['shard_live{shard="2"}'] == 0.0
assert abs(snap["gauges"]["shard_coverage"] - 3/4) < 1e-9

srv.revive_shard(2)                  # explicit revival refreshes the beat
srv.submit_many(Q)
rs = srv.drain()
assert all(r.ok and r.coverage == 1.0 for r in rs)
print("OK")
""", n_devices=4)
    assert "OK" in out
