"""Search-engine behaviour: recall, adaptivity, counters, invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (
    BuildParams,
    SearchParams,
    build_approx,
    error_bounded_search,
    greedy_search,
    search,
)

from conftest import recall_at_k


@pytest.fixture(scope="module")
def approx_graph(small_corpus):
    p = BuildParams(max_degree=24, beam_width=48, t=24, iters=3, block=512)
    return build_approx(small_corpus["base"], p)


def test_recall_reasonable(approx_graph, small_corpus):
    res = error_bounded_search(approx_graph,
                               jnp.asarray(small_corpus["queries"]),
                               k=10, alpha=2.0, l_max=128)
    assert recall_at_k(res.ids, small_corpus["gt_i"], 10) > 0.85


def test_greedy_l_monotone_recall(approx_graph, small_corpus):
    """Wider greedy beams can only help recall (within noise)."""
    rs = []
    for l in (10, 32, 96):
        res = greedy_search(approx_graph, jnp.asarray(small_corpus["queries"]),
                            k=10, l=l)
        rs.append(recall_at_k(res.ids, small_corpus["gt_i"], 10))
    assert rs[0] <= rs[1] + 0.05 and rs[1] <= rs[2] + 0.05
    assert rs[2] > 0.85


def test_alpha_widens_search(approx_graph, small_corpus):
    """Larger α ⇒ stricter stop ⇒ monotonically more work (Alg. 3)."""
    work = []
    for alpha in (1.0, 1.15, 1.4):
        res = error_bounded_search(
            approx_graph, jnp.asarray(small_corpus["queries"]),
            k=10, alpha=alpha, l_max=128)
        work.append(float(np.mean(np.asarray(res.n_dist_comps))))
    assert work[0] <= work[1] <= work[2]


def test_results_sorted_and_valid(approx_graph, small_corpus):
    res = error_bounded_search(approx_graph,
                               jnp.asarray(small_corpus["queries"]),
                               k=10, alpha=1.5, l_max=96)
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    n = small_corpus["base"].shape[0]
    assert ((ids >= 0) & (ids < n)).all()
    assert (np.diff(dists, axis=1) >= -1e-5).all()
    # distances are true Euclidean distances
    rows = small_corpus["base"][ids.ravel()].reshape(ids.shape + (-1,))
    expect = np.linalg.norm(rows - small_corpus["queries"][:, None, :], axis=-1)
    np.testing.assert_allclose(dists, expect, rtol=1e-4, atol=1e-4)


def test_no_duplicate_results(approx_graph, small_corpus):
    res = error_bounded_search(approx_graph,
                               jnp.asarray(small_corpus["queries"]),
                               k=10, alpha=1.5, l_max=96)
    ids = np.asarray(res.ids)
    for row in ids:
        assert len(set(row.tolist())) == len(row)


def test_deterministic(approx_graph, small_corpus):
    q = jnp.asarray(small_corpus["queries"])
    r1 = error_bounded_search(approx_graph, q, k=10, alpha=1.3, l_max=96)
    r2 = error_bounded_search(approx_graph, q, k=10, alpha=1.3, l_max=96)
    assert (np.asarray(r1.ids) == np.asarray(r2.ids)).all()


def test_counters_consistent(approx_graph, small_corpus):
    res = error_bounded_search(approx_graph,
                               jnp.asarray(small_corpus["queries"]),
                               k=10, alpha=1.3, l_max=96)
    n_dist = np.asarray(res.n_dist_comps)
    hops = np.asarray(res.n_hops)
    M = approx_graph.max_degree
    assert (n_dist >= hops).all()            # ≥1 per expansion + start
    assert (n_dist <= hops * M + 1).all()    # ≤ M per expansion


def test_faithful_prune_variant_runs(approx_graph, small_corpus):
    p = SearchParams(k=10, l0=10, l_max=96, alpha=1.3, adaptive=True,
                     max_hops=1024)
    res = search(approx_graph, jnp.asarray(small_corpus["queries"]), p,
                 faithful_prune=True)
    assert recall_at_k(res.ids, small_corpus["gt_i"], 10) > 0.4


@settings(max_examples=10, deadline=None)
@given(k=st.integers(1, 10), alpha=st.floats(1.0, 2.0))
def test_property_topk_prefix_consistency(approx_graph, small_corpus, k, alpha):
    """R_j(q) for j < k is a prefix of R_k(q) distances (non-decreasing)."""
    res = error_bounded_search(approx_graph,
                               jnp.asarray(small_corpus["queries"][:8]),
                               k=k, alpha=alpha, l_max=64)
    d = np.asarray(res.dists)
    assert d.shape[1] == k
    assert (np.diff(d, axis=1) >= -1e-5).all()
