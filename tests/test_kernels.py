"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitdot import ref as bitref
from repro.kernels.bitdot.ops import bitdot, fused_estimate
from repro.kernels.l2dist import ref as l2ref
from repro.kernels.l2dist.ops import batched_l2, gather_l2

SHAPES_L2 = [
    (1, 8, 16), (4, 24, 100), (2, 64, 128), (3, 17, 33), (8, 32, 256),
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("B,M,d", SHAPES_L2)
@pytest.mark.parametrize("dtype", DTYPES)
def test_batched_l2_vs_ref(B, M, d, dtype):
    rng = np.random.default_rng(B * 1000 + M + d)
    rows = jnp.asarray(rng.normal(size=(B, M, d)).astype(np.float32)).astype(dtype)
    qs = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32)).astype(dtype)
    out = batched_l2(rows, qs)
    expect = l2ref.batched_l2_ref(rows, qs)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol * d)


@pytest.mark.parametrize("B,M,d", [(2, 16, 24), (4, 32, 128), (1, 7, 65)])
def test_gather_l2_vs_ref(B, M, d):
    rng = np.random.default_rng(7)
    n = 200
    base = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    ids = rng.integers(0, n, (B, M)).astype(np.int32)
    ids[0, 0] = -1                      # INVALID handling
    ids = jnp.asarray(ids)
    qs = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    out = np.asarray(gather_l2(base, ids, qs))
    expect = np.asarray(l2ref.gather_l2_ref(base, jnp.maximum(ids, 0), qs))
    assert np.isinf(out[0, 0])
    mask = np.asarray(ids) >= 0
    np.testing.assert_allclose(out[mask], expect[mask], rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("m,d", [(8, 32), (100, 100), (300, 128), (17, 257)])
def test_bitdot_vs_ref(m, d):
    rng = np.random.default_rng(m + d)
    W = (d + 31) // 32
    codes = jnp.asarray(
        rng.integers(0, 2**32, (m, W), dtype=np.uint64).astype(np.uint32))
    q = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    out = np.asarray(bitdot(codes, q))
    expect = np.asarray(bitref.bitdot_ref(codes, q))
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,d,tm", [(64, 64, 16), (200, 128, 64), (9, 96, 8)])
def test_fused_estimate_vs_ref(m, d, tm):
    rng = np.random.default_rng(m)
    W = (d + 31) // 32
    codes = jnp.asarray(
        rng.integers(0, 2**32, (m, W), dtype=np.uint64).astype(np.uint32))
    norms = jnp.asarray((0.5 + np.abs(rng.normal(size=m))).astype(np.float32))
    ipxo = jnp.asarray((0.5 + 0.4 * rng.random(m)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    nq = jnp.float32(1.7)
    out = np.asarray(fused_estimate(codes, norms, ipxo, q, nq, d, tm=tm))
    expect = np.asarray(bitref.estimate_sqdist_ref(codes, norms, ipxo, q, nq, d))
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-3)


def test_bitdot_matches_core_rabitq(small_corpus):
    """The kernel slot in core.rabitq.estimate_sqdist produces identical
    estimates to the pure-jnp default path."""
    from repro.core import rabitq

    base = small_corpus["base"][:256]
    codes = rabitq.fit(jnp.asarray(base), jax.random.PRNGKey(0))
    ctx = rabitq.prepare_query(codes, jnp.asarray(small_corpus["queries"][0]))
    ids = jnp.arange(128, dtype=jnp.int32)
    d_default = np.asarray(rabitq.estimate_sqdist(codes, ctx, ids))
    d_kernel = np.asarray(rabitq.estimate_sqdist(codes, ctx, ids,
                                                 bitdot_fn=bitdot))
    np.testing.assert_allclose(d_default, d_kernel, rtol=1e-4, atol=1e-3)


def test_kernel_use_ref_flag():
    rng = np.random.default_rng(0)
    rows = jnp.asarray(rng.normal(size=(2, 8, 16)).astype(np.float32))
    qs = jnp.asarray(rng.normal(size=(2, 16)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(batched_l2(rows, qs)),
        np.asarray(batched_l2(rows, qs, use_ref=True)), rtol=1e-5, atol=1e-5)
