"""Self-healing shard repair: detect → rebuild → verify → atomic install.

The ISSUE-level acceptance claims pinned here:

* after N injected shard deaths with auto-repair enabled,
  ``ShardHealthRegistry.coverage()`` returns to 1.0 without any operator
  ``mark_live``/``revive_shard`` call;
* a crash mid-install never flips the participation mask;
* the repaired shard is **bit-identical** to a from-scratch rebuild (and
  to the slot the original ``build_sharded`` produced, because the store
  snapshots the exact padded rows and ``build_shard`` derives the same
  per-shard seed).

The controller tests run in-process (the controller, store, registry and
``host_reference_merge`` are all host-side; single default device is
fine).  The end-to-end chaos test spawns a 4-device subprocess like
``test_distributed.py``.  Everything rides the ``faults`` CI matrix.
"""

import subprocess
import sys

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import BuildParams, SearchParams  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    ShardHealthRegistry,
    build_replicated,
    build_shard,
    build_sharded,
)
from repro.core.repair import (  # noqa: E402
    RepairConfig,
    RepairController,
    ShardVectorStore,
)
from repro.obs import MetricsRegistry, snapshot  # noqa: E402
from repro.testing import (  # noqa: E402
    RepairFaultPlan,
    SimulatedCrash,
    corrupt_shard_source,
)

pytestmark = pytest.mark.faults

# Build params chosen so that every rebuilt shard passes the audit gate
# cleanly on the fixture corpus (weaker builds — fewer iters, lower degree
# — legitimately leave unreachable nodes, which the gate MUST reject; see
# test_audit_gate_rejects_defective_rebuild).
BP = BuildParams(max_degree=12, beam_width=24, t=10, iters=3, block=128,
                 delta=0.5)
N, DIM, S, SEED = 509, 12, 4, 3


@pytest.fixture(scope="module")
def corpus():
    return np.random.default_rng(0).standard_normal((N, DIM)).astype(
        np.float32)


@pytest.fixture(scope="module")
def built(corpus):
    return build_sharded(corpus, S, BP, seed=SEED)


@pytest.fixture(scope="module")
def store(corpus, tmp_path_factory):
    d = tmp_path_factory.mktemp("shard_store")
    return ShardVectorStore.create(str(d), corpus, S, params=BP, seed=SEED)


def _controller(store, sidx, registry=None, **kw):
    """(controller, registry, holder, clock) over a mutable index holder.

    ``install_slot`` is purely functional, so the module-scoped ``built``
    index is never mutated — each test gets its own holder/registry."""
    t = {"now": 0.0}
    reg = registry or ShardHealthRegistry(S, clock=lambda: t["now"])
    holder = {"sidx": sidx}
    ctl = RepairController(store, reg,
                           get_sidx=lambda: holder["sidx"],
                           set_sidx=lambda x: holder.__setitem__("sidx", x),
                           clock=lambda: t["now"], **kw)
    return ctl, reg, holder, t


def _assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Happy path: coverage restored, bit-identical, fully observable
# ---------------------------------------------------------------------------


def test_repair_restores_coverage_bit_identically(store, built):
    m = MetricsRegistry()
    ctl, reg, holder, t = _controller(store, built, metrics=m)
    reg.mark_dead(1)
    reg.mark_dead(3)
    assert reg.coverage() == 0.5

    out1 = ctl.sweep()                      # default budget: one per sweep
    assert [o.status for o in out1] == ["succeeded"]
    assert out1[0].shard == 1 and out1[0].attempt == 1
    assert reg.coverage() == 0.75

    out2 = ctl.sweep()
    assert [(o.shard, o.status) for o in out2] == [(3, "succeeded")]
    assert reg.coverage() == 1.0            # no operator mark_live anywhere

    # the healed index is bit-identical to the original build …
    _assert_tree_equal(holder["sidx"], built)
    # … and the repaired slot is bit-identical to a from-scratch rebuild
    fresh = store.build_shard(3)
    slot = jax.tree.map(lambda x: x[3], holder["sidx"].index)
    _assert_tree_equal(slot, fresh)

    assert (ctl.n_repaired, ctl.n_failed, ctl.n_sweeps) == (2, 0, 2)
    snap = snapshot(m)
    assert snap["counters"]["repair_started_total"] == 2
    assert snap["counters"]["repair_succeeded_total"] == 2
    assert "repair_failed_total" not in snap["counters"]
    assert snap["gauges"]['shard_under_repair{shard="1"}'] == 0.0
    assert snap["gauges"]['shard_under_repair{shard="3"}'] == 0.0
    assert snap["histograms"]["repair_duration_seconds"]["count"] == 2
    names = [e["name"] for e in snap["events"]]
    assert names.count("repair_started") == 2
    assert names.count("repair_succeeded") == 2
    done = [e for e in snap["events"] if e["name"] == "repair_succeeded"]
    assert sorted(e["shard"] for e in done) == [1, 3]


def test_repair_prioritizes_coverage_holes(store, corpus):
    """A shard with NO live replica is repaired before a dead replica of a
    covered shard — and with budget 1 the hole closes in sweep one."""
    t_reg = {"now": 0.0}
    reg = ShardHealthRegistry(S, n_replicas=2, clock=lambda: t_reg["now"])
    rep = build_replicated(corpus, S, n_replicas=2, params=BP, seed=SEED)
    ctl, reg, holder, t = _controller(store, rep, registry=reg)
    reg.mark_dead(0, 0)                     # covered: (0, 1) still lives
    reg.mark_dead(2, 0)                     # hole: both replicas dead
    reg.mark_dead(2, 1)
    assert reg.coverage() == 0.75
    assert ctl.pending() == [(2, 0), (2, 1), (0, 0)]

    out = ctl.sweep()
    assert [(o.shard, o.replica) for o in out] == [(2, 0)]
    assert reg.coverage() == 1.0            # hole closed first
    ctl.sweep()
    ctl.sweep()
    assert ctl.pending() == []
    _assert_tree_equal(holder["sidx"], rep)


# ---------------------------------------------------------------------------
# Contained failures: retry with exponential backoff, no regression
# ---------------------------------------------------------------------------


def test_rebuild_failures_back_off_and_retry(store, built):
    m = MetricsRegistry()
    plan = RepairFaultPlan(fail_rebuilds=2)
    hook = plan.hook()
    ctl, reg, holder, t = _controller(store, built, metrics=m,
                                      fault_hook=hook)
    reg.mark_dead(2)

    out = ctl.sweep()                       # attempt 1 fails → backoff 0.5 s
    assert [o.status for o in out] == ["failed"]
    assert "RepairFault" in out[0].error
    assert holder["sidx"] is built          # contained: index untouched
    assert reg.coverage() == 0.75

    t["now"] = 0.25
    assert ctl.sweep() == []                # still inside the backoff window

    t["now"] = 0.6
    out = ctl.sweep()                       # attempt 2 fails → backoff 1.0 s
    assert [o.attempt for o in out] == [2]
    assert out[0].status == "failed"

    t["now"] = 1.0
    assert ctl.sweep() == []                # 0.6 + 1.0 > 1.0: still waiting

    t["now"] = 2.0
    out = ctl.sweep()
    assert [(o.status, o.attempt) for o in out] == [("succeeded", 3)]
    assert reg.coverage() == 1.0
    _assert_tree_equal(holder["sidx"], built)
    assert hook.visits["rebuild"] == 3
    assert (ctl.n_repaired, ctl.n_failed) == (1, 2)
    snap = snapshot(m)
    assert snap["counters"]["repair_started_total"] == 3
    assert snap["counters"]["repair_failed_total"] == 2
    assert snap["counters"]["repair_succeeded_total"] == 1
    fails = [e for e in snap["events"] if e["name"] == "repair_failed"]
    assert [e["retry_in_s"] for e in fails] == [0.5, 1.0]


def test_corrupted_source_fails_cleanly_then_recovers(tmp_path, corpus,
                                                      built):
    """Both corruption modes are caught by verify-on-read: the repair fails
    (no install, no mask flip), and once the source is re-replicated the
    same controller heals on the next eligible sweep."""
    d = str(tmp_path / "store")
    st = ShardVectorStore.create(d, corpus, S, params=BP, seed=SEED)
    corrupt_shard_source(d, 1, mode="truncate")
    corrupt_shard_source(d, 2, mode="checksum")

    ctl, reg, holder, t = _controller(store=st, sidx=built,
                                      config=RepairConfig(budget_per_sweep=2))
    reg.mark_dead(1)
    reg.mark_dead(2)
    out = ctl.sweep()
    assert [o.status for o in out] == ["failed", "failed"]
    assert all("ShardSourceCorruptError" in o.error for o in out)
    assert holder["sidx"] is built
    assert reg.coverage() == 0.5
    assert not reg._live[1, 0] and not reg._live[2, 0]

    ShardVectorStore.create(d, corpus, S, params=BP, seed=SEED)  # re-replicate
    t["now"] = 10.0                          # past both backoff windows
    out = ctl.sweep()
    assert [o.status for o in out] == ["succeeded", "succeeded"]
    assert reg.coverage() == 1.0
    _assert_tree_equal(holder["sidx"], built)


# ---------------------------------------------------------------------------
# Install crashes: the atomic-install rule
# ---------------------------------------------------------------------------


def test_crash_before_install_leaves_index_and_mask_untouched(store, built):
    hook = RepairFaultPlan(crash_point="before_install").hook()
    ctl, reg, holder, t = _controller(store, built, fault_hook=hook)
    reg.mark_dead(2)
    with pytest.raises(SimulatedCrash):
        ctl.sweep()
    assert holder["sidx"] is built          # nothing installed
    assert not reg._live[2, 0]              # mask never flipped
    assert reg.coverage() == 0.75

    # "process restart": a fresh controller over the same state heals
    ctl2, _, _, _ = _controller(store, holder["sidx"], registry=reg)
    out = ctl2.sweep()
    assert [o.status for o in out] == ["succeeded"]
    assert reg.coverage() == 1.0


def test_crash_mid_install_never_flips_participation_mask(store, built):
    """The verified index may land (install is one atomic pytree swap) but
    the mask flips only AFTER it — dying between the two leaves a dead
    slot serving nothing, never a live slot serving an unverified one."""
    hook = RepairFaultPlan(crash_point="mid_install").hook()
    ctl, reg, holder, t = _controller(store, built, fault_hook=hook)
    reg.mark_dead(2)
    with pytest.raises(SimulatedCrash):
        ctl.sweep()
    assert not reg._live[2, 0]              # the acceptance claim
    assert reg.coverage() == 0.75
    _assert_tree_equal(holder["sidx"], built)   # what landed was verified

    ctl2, _, holder2, _ = _controller(store, holder["sidx"], registry=reg)
    out = ctl2.sweep()
    assert [o.status for o in out] == ["succeeded"]
    assert reg.coverage() == 1.0
    _assert_tree_equal(holder2["sidx"], built)


def test_crash_after_install_is_fully_recovered(store, built):
    """Dying after ``mark_live`` is the benign case: the repair completed;
    a restarted controller finds nothing to do."""
    hook = RepairFaultPlan(crash_point="after_install").hook()
    ctl, reg, holder, t = _controller(store, built, fault_hook=hook)
    reg.mark_dead(3)
    with pytest.raises(SimulatedCrash):
        ctl.sweep()
    assert reg.coverage() == 1.0
    _assert_tree_equal(holder["sidx"], built)
    ctl2, _, _, _ = _controller(store, holder["sidx"], registry=reg)
    assert ctl2.pending() == []
    assert ctl2.sweep() == []


# ---------------------------------------------------------------------------
# Verification gate and plan/controller validation
# ---------------------------------------------------------------------------


def test_audit_gate_rejects_defective_rebuild(store, built, monkeypatch):
    """A rebuild that produces a defective graph (one node orphaned — no
    in-edges, so unreachable from the medoid) must be rejected by the
    audit gate: the repair fails, nothing installs, the mask stays down.
    Once rebuilds are healthy again the same controller heals."""
    import dataclasses as dc

    import jax.numpy as jnp

    import repro.core.repair as repair_mod

    def sabotaged_build(rows, shard, params=None, quantized=False, seed=0):
        g = build_shard(rows, shard, params, quantized, seed)
        victim = (int(g.medoid) + 1) % g.n
        nbrs = np.asarray(g.neighbors).copy()
        nbrs[nbrs == victim] = -1           # orphan: no path can reach it
        return dc.replace(g, neighbors=jnp.asarray(nbrs))

    monkeypatch.setattr(repair_mod, "build_shard", sabotaged_build)
    ctl, reg, holder, t = _controller(store, built)
    reg.mark_dead(2)
    out = ctl.sweep()
    assert [o.status for o in out] == ["failed"]
    assert "RepairError" in out[0].error and "audit" in out[0].error
    assert holder["sidx"] is built          # nothing installed
    assert not reg._live[2, 0]              # mask never flipped

    monkeypatch.undo()                      # rebuilds are healthy again
    t["now"] = 10.0                         # past the backoff window
    out = ctl.sweep()
    assert [o.status for o in out] == ["succeeded"]
    assert reg.coverage() == 1.0
    _assert_tree_equal(holder["sidx"], built)


def test_repair_plan_and_controller_validation(store, built, corpus,
                                               tmp_path):
    with pytest.raises(ValueError, match="crash_point"):
        RepairFaultPlan(crash_point="rebuild")      # contained phase: no-op
    with pytest.raises(ValueError, match="shards"):
        st2 = ShardVectorStore.create(str(tmp_path / "s2"), corpus, 2,
                                      params=BP, seed=SEED)
        RepairController(st2, ShardHealthRegistry(S),
                         get_sidx=lambda: built, set_sidx=lambda _: None)


# ---------------------------------------------------------------------------
# End-to-end chaos: kill shards under load, auto-repair heals the server
# ---------------------------------------------------------------------------

_PREAMBLE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import sys; sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
"""


def _run(body: str, n_devices: int = 4, timeout: int = 560) -> str:
    code = _PREAMBLE.format(n=n_devices) + body
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, cwd="/root/repo")
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
    return proc.stdout


def test_chaos_shard_deaths_self_heal_under_load():
    """Heartbeat silence kills two shards mid-stream; the server's repair
    sweep (after the health check, before dispatch) restores coverage to
    1.0 with ZERO operator calls.  Post-repair responses are bit-identical
    to the healthy baseline AND to the host reference oracle, the healed
    index is bit-identical to a from-scratch rebuild, and every response
    along the way — including the degraded one — honors the paper's (1/δ)
    bound on the rows it could see."""
    out = _run("""
import os, tempfile
from repro.core import BuildParams, SearchParams
from repro.core.distributed import build_sharded, host_reference_merge
from repro.core.repair import RepairConfig, ShardVectorStore
from repro.obs import MetricsRegistry, snapshot
from repro.serve import ResilienceConfig, ShardedResilientAnnServer
from repro.testing import check_delta_bound, exact_knn

seed = int(os.environ.get("REPRO_FAULT_SEED", "0"))
rng = np.random.default_rng(seed)
DELTA = 0.5
# dim 8 + these build params give audit-clean shards for every fault seed
# in the CI matrix — the repair gate must judge rebuilds repairable, and a
# graph the gate would reject can never self-heal (by design: the gate is
# exactly as strict for a rebuild as verify.audit is for a fresh build)
X = rng.standard_normal(size=(512, 8)).astype(np.float32)  # 4*128: no pads
Q = rng.standard_normal(size=(12, 8)).astype(np.float32)
bp = BuildParams(max_degree=12, beam_width=24, t=10, iters=3, block=128,
                 delta=DELTA)
mesh = jax.make_mesh((4,), ("data",))
sidx = build_sharded(X, 4, bp, seed=7)
store_dir = tempfile.mkdtemp()
store = ShardVectorStore.create(store_dir, X, 4, params=bp, seed=7)
params = SearchParams(k=5, l0=16, l_max=32, adaptive=False, max_hops=256,
                      beam_width=1)
t = {"now": 0.0}
m = MetricsRegistry()
srv = ShardedResilientAnnServer(sidx, params, mesh,
                                config=ResilienceConfig(backoff_s=0.0),
                                clock=lambda: t["now"],
                                health_deadline_s=5.0, metrics=m,
                                auto_repair=RepairConfig(budget_per_sweep=1),
                                vector_store=store)

def ids_dists(rs):
    return (np.stack([np.asarray(r.ids) for r in rs]),
            np.stack([np.asarray(r.dists) for r in rs]))

srv.submit_many(Q)                          # stage 1: healthy baseline
rs0 = srv.drain()
assert all(r.ok and r.coverage == 1.0 for r in rs0)
base_ids, base_d = ids_dists(rs0)

t["now"] = 4.0                              # shards 1, 2 go silent …
for s in (0, 3):
    srv.heartbeat(s)
t["now"] = 7.0                              # … and age past the deadline

srv.submit_many(Q)                          # stage 2: checker kills both,
rs1 = srv.drain()                           # budget-1 sweep repairs ONE
assert srv.health_checker.n_killed == 2
assert all(r.ok and abs(r.coverage - 3/4) < 1e-9 for r in rs1)

srv.submit_many(Q)                          # stage 3: second sweep heals
rs2 = srv.drain()                           # the other shard
assert all(r.ok and r.coverage == 1.0 for r in rs2)
assert srv.repair.n_repaired == 2           # no revive_shard was ever called
snap = snapshot(m)
assert snap["counters"]["repair_succeeded_total"] == 2
assert snap["counters"]["shard_marked_dead_total"] == 2

# healed responses are bit-identical to the healthy baseline …
ids2, d2 = ids_dists(rs2)
assert np.array_equal(ids2, base_ids) and np.array_equal(d2, base_d)
# … and to the host reference oracle over the healed index
hr_ids, hr_d = host_reference_merge(srv.index, srv.registry, Q, params)
assert np.array_equal(ids2, np.asarray(hr_ids))

# the healed index is bit-identical to a from-scratch rebuild
fresh = build_sharded(X, 4, bp, seed=7)
for a, b in zip(jax.tree.leaves(srv.index), jax.tree.leaves(fresh)):
    assert np.array_equal(np.asarray(a), np.asarray(b))

# Theorem-1 (1/δ) bound: healthy stages against the full corpus; the
# degraded stage against the corpus it could actually see.  The repair
# queue is hole-first then by shard id, so shard 1 heals in stage 2 and
# shard 2 (global rows [256, 384)) is the one still dark there.
orc_d, _ = exact_knn(X, Q, 5)
assert check_delta_bound(base_d, orc_d, DELTA) is None
assert check_delta_bound(d2, orc_d, DELTA) is None
ids1, d1 = ids_dists(rs1)
assert not ((ids1 >= 256) & (ids1 < 384)).any()   # dead rows never served
visible = np.ones(512, bool)
visible[256:384] = False
orc1_d, _ = exact_knn(X[visible], Q, 5)
assert check_delta_bound(d1, orc1_d, DELTA) is None
print("OK")
""")
    assert "OK" in out
