"""Oracle-based conformance suite (marker ``conformance``).

The correctness contract is the paper's own guarantee, checked against
implementation-independent oracles (``repro.testing.oracle``): brute-force
exact k-NN in float64 numpy, plus the per-query ``(1/δ)`` approximation
bound that Theorem 1 proves for *any* greedy search on a δ-EMG.  No engine
is ever compared against another engine — parity between two approximate
implementations is circular and cannot catch a shared bug.

Layers:

* **δ-bound conformance** — every engine (beam search, faithful-prune
  variant, Alg.-5 probing, AGS) × backend × beam_width combination must
  satisfy ``returned_dist ≤ (1/δ)·d*`` for every query at every rank,
  against an exact Algorithm-2 build with known construction δ.
* **Honesty** — returned distances must *be* the true Euclidean distances
  of the returned ids (an engine must not be able to pass the bound by
  misreporting), ids must be valid and duplicate-free, dists sorted.
* **Metamorphic invariants** — corpus-row permutation leaves the bound
  intact (the oracle is permutation-equivariant), an injected duplicate
  point is found at distance 0, and a query equal to a corpus point
  returns distance 0 at rank 1.
* **Randomized corpora** — a parametrized seed sweep locally plus
  hypothesis-driven seeds in CI (``REPRO_CONFORMANCE_SEED`` rotates the
  base seed across the CI matrix).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import SearchParams, ags_search, build_exact, probing_search, search
from repro.core.emqg import from_graph
from repro.testing.oracle import check_delta_bound, exact_knn, recall_at_k

from conftest import gmm

pytestmark = pytest.mark.conformance

DELTA = 0.2          # construction δ — bound factor 1/δ = 5
K = 5


def _make_params(beam_width: int, l_max: int = 32,
                 max_hops: int = 256) -> SearchParams:
    return SearchParams(k=K, l0=8, l_max=l_max, alpha=1.2, adaptive=True,
                        max_hops=max_hops, beam_width=beam_width)


def _build(seed: int, n: int = 400, d: int = 16):
    """Exact Alg.-2 δ-EMG over a clustered corpus, plus queries + oracle."""
    base = gmm(n, d, 8, seed=seed)
    queries = gmm(16, d, 8, seed=seed + 1)
    graph = build_exact(jnp.asarray(base), delta=DELTA)
    oracle_d, oracle_i = exact_knn(base, queries, K)
    return base, queries, graph, oracle_d, oracle_i


@pytest.fixture(scope="module")
def fix(conformance_seed):
    base, queries, graph, oracle_d, oracle_i = _build(conformance_seed)
    return {"base": base, "queries": queries, "graph": graph,
            "emqg": from_graph(graph), "oracle_d": oracle_d,
            "oracle_i": oracle_i}


def _run(engine: str, fix, q, params: SearchParams, backend: str):
    if engine == "beam":
        return search(fix["graph"], q, params, backend=backend)
    if engine == "faithful":
        return search(fix["graph"], q, params, faithful_prune=True,
                      backend=backend)
    if engine == "probing":
        return probing_search(fix["emqg"], q, params, backend=backend)
    if engine == "ags":
        return ags_search(fix["emqg"], q, params, backend=backend)
    raise ValueError(engine)


ENGINES = ("beam", "faithful", "probing", "ags")


def _assert_conformant(res, fix, base=None):
    """δ-bound + honesty checks against the brute-force oracle."""
    base = fix["base"] if base is None else base
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    n = base.shape[0]
    assert ((ids >= 0) & (ids < n)).all()
    for row in ids:
        assert len(set(row.tolist())) == len(row)
    assert (np.diff(dists, axis=1) >= -1e-5).all()
    # honesty: reported distances are the true distances of the returned ids
    true = np.linalg.norm(
        base[ids.ravel()].reshape(ids.shape + (-1,))
        - np.asarray(fix["queries"])[:, None, :], axis=-1)
    np.testing.assert_allclose(dists, true, rtol=1e-4, atol=1e-4)
    # the paper's guarantee, per query, per rank
    assert check_delta_bound(dists, fix["oracle_d"], DELTA) is None


# ---------------------------------------------------------------------------
# δ-bound conformance: every engine × backend × beam_width combination.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("beam_width", [1, 4])
def test_delta_bound_jnp(fix, engine, beam_width):
    q = jnp.asarray(fix["queries"])
    res = _run(engine, fix, q, _make_params(beam_width), backend="jnp")
    _assert_conformant(res, fix)


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("backend", ["kernel", "kernel_tiled"])
def test_delta_bound_kernel_backends(fix, engine, backend):
    """Pallas gather+L2 backends (interpret mode on CPU — kept small: the
    bound must hold on the kernel path, not just the XLA reference)."""
    q = jnp.asarray(fix["queries"][:4])
    res = _run(engine, fix, q,
               _make_params(beam_width=2, l_max=16, max_hops=96),
               backend=backend)
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    assert ((ids >= 0) & (ids < fix["base"].shape[0])).all()
    assert check_delta_bound(dists, fix["oracle_d"][:4], DELTA) is None


def test_adaptive_alpha_tightens_bound(fix):
    """Queries whose α-rule actually fired (not saturated) carry the
    tighter 1/(δ·α) bound of Algorithm 3."""
    q = jnp.asarray(fix["queries"])
    p = _make_params(beam_width=1)
    res = search(fix["graph"], q, p, backend="jnp")
    sat = np.asarray(res.saturated)
    if (~sat).any():
        assert check_delta_bound(np.asarray(res.dists)[~sat],
                                 fix["oracle_d"][~sat], DELTA,
                                 alpha=p.alpha) is None


def test_ags_rerank_recall_floor(fix):
    """AGS guides the walk with approximate distances, so beyond the bound
    its exact rerank should land most of the true neighbors here."""
    q = jnp.asarray(fix["queries"])
    res = ags_search(fix["emqg"], q, _make_params(beam_width=1))
    assert recall_at_k(np.asarray(res.ids), fix["oracle_i"]) >= 0.6
    # counters split correctly: traversal is approximate, rerank exact
    assert (np.asarray(res.n_approx_comps) > 0).all()
    assert (np.asarray(res.n_dist_comps) >= K).all()


# ---------------------------------------------------------------------------
# Metamorphic invariants.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_corpus_permutation_keeps_bound(fix, conformance_seed, engine):
    """Relabeling corpus rows changes ids but not geometry: the oracle
    distances are permutation-invariant and the bound must still hold on
    an index built from the permuted corpus."""
    rng = np.random.default_rng(conformance_seed + 100)
    perm = rng.permutation(fix["base"].shape[0])
    base_p = fix["base"][perm]
    graph_p = build_exact(jnp.asarray(base_p), delta=DELTA)
    fix_p = {"base": base_p, "queries": fix["queries"], "graph": graph_p,
             "emqg": from_graph(graph_p), "oracle_d": fix["oracle_d"]}
    q = jnp.asarray(fix["queries"])
    res = _run(engine, fix_p, q, _make_params(beam_width=1), backend="jnp")
    _assert_conformant(res, fix_p)


def test_duplicate_point_found_at_zero(conformance_seed):
    """Injecting an exact duplicate of a corpus row must not break the
    index, and querying that point returns distance 0 at rank 1."""
    base = gmm(200, 12, 6, seed=conformance_seed + 7)
    dup_row = base[17]
    base = np.concatenate([base, dup_row[None, :]], axis=0)
    graph = build_exact(jnp.asarray(base), delta=DELTA)
    q = jnp.asarray(dup_row[None, :])
    for engine, idx in (("beam", graph), ("probing", from_graph(graph))):
        run = search if engine == "beam" else probing_search
        res = run(idx, q, _make_params(beam_width=1), backend="jnp")
        assert float(np.asarray(res.dists)[0, 0]) < 1e-3, engine
        assert int(np.asarray(res.ids)[0, 0]) in (17, 200), engine


@pytest.mark.parametrize("engine", ENGINES)
def test_query_equals_corpus_point(fix, conformance_seed, engine):
    """q ∈ corpus ⇒ d* = 0, so the (1/δ) bound forces the engine to return
    that exact point (distance 0) at rank 1."""
    rng = np.random.default_rng(conformance_seed + 3)
    pick = rng.choice(fix["base"].shape[0], size=8, replace=False)
    q = jnp.asarray(fix["base"][pick])
    fix_q = dict(fix, queries=fix["base"][pick],
                 oracle_d=exact_knn(fix["base"], fix["base"][pick], K)[0])
    res = _run(engine, fix_q, q, _make_params(beam_width=1), backend="jnp")
    dists = np.asarray(res.dists)
    ids = np.asarray(res.ids)
    assert (dists[:, 0] < 1e-3).all()
    np.testing.assert_allclose(fix["base"][ids[:, 0]], fix["base"][pick],
                               rtol=1e-5, atol=1e-5)
    _assert_conformant(res, fix_q)


# ---------------------------------------------------------------------------
# Randomized corpora.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("offset", [11, 29])
def test_randomized_corpora_sweep(conformance_seed, offset):
    """Fresh corpus + queries per seed; bound must hold for the beam and
    faithful-prune engines (local, hypothesis-free version of the sweep)."""
    base, queries, graph, oracle_d, _ = _build(conformance_seed + offset,
                                               n=256, d=12)
    q = jnp.asarray(queries)
    for faithful in (False, True):
        res = search(graph, q, _make_params(beam_width=1),
                     faithful_prune=faithful, backend="jnp")
        assert check_delta_bound(np.asarray(res.dists), oracle_d,
                                 DELTA) is None


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_property_delta_bound_random_corpus(seed):
    """Hypothesis-driven corpora (CI): any seed, same guarantee.  Fixed
    shapes keep jit cache hits across examples."""
    base, queries, graph, oracle_d, _ = _build(seed, n=160, d=8)
    res = search(graph, jnp.asarray(queries),
                 _make_params(beam_width=2, l_max=24, max_hops=128),
                 backend="jnp")
    assert check_delta_bound(np.asarray(res.dists), oracle_d, DELTA) is None


# ---------------------------------------------------------------------------
# Oracle self-checks (the oracle must be trustworthy before it judges).
# ---------------------------------------------------------------------------

def test_oracle_permutation_equivariant(conformance_seed):
    base = gmm(100, 8, 4, seed=conformance_seed + 5)
    queries = gmm(6, 8, 4, seed=conformance_seed + 6)
    d0, i0 = exact_knn(base, queries, 4)
    perm = np.random.default_rng(0).permutation(100)
    d1, i1 = exact_knn(base[perm], queries, 4)
    np.testing.assert_allclose(d0, d1, rtol=1e-12, atol=1e-12)
    np.testing.assert_array_equal(perm[i1], i0)


def test_oracle_detects_violation():
    """check_delta_bound must actually fire on a planted violation."""
    oracle = np.full((2, 3), 1.0)
    good = np.full((2, 3), 1.0 / DELTA * 0.99)
    bad = good.copy()
    bad[1, 2] = 1.0 / DELTA * 1.05
    assert check_delta_bound(good, oracle, DELTA) is None
    msg = check_delta_bound(bad, oracle, DELTA)
    assert msg is not None and "query 1 rank 2" in msg
