"""Beam-engine self-consistency: determinism goldens, counter invariants,
and unit tests for the packed visited bitset and the tiled gather+L2 kernel.

The engine's *correctness* contract lives in ``tests/test_conformance.py``
(brute-force oracle + the paper's (1/δ) bound — implementation-independent).
This file pins the engine's *behavioral* contract instead:

* **W=1 determinism goldens** — greedy best-first is a deterministic
  schedule: identical ids/dists/hop-counts across runs and across distance
  backends (jnp vs the Pallas kernels, which must be bit-compatible enough
  that tie-breaks never flip on clustered data).
* **Counter invariants** — ``n_encounters`` counts candidate encounters
  pre-dedup, so it dominates ``n_dist_comps`` everywhere, and widening the
  frontier (W↑) or the stop margin (α↑) can only increase the measured
  work (Exp-5's metric must be monotone in the knobs that widen search).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BuildParams,
    SearchParams,
    build_approx,
    build_emqg,
    probing_search,
    search,
)
from repro.core.bitset import (
    bitset_make,
    bitset_set,
    bitset_test,
    bitset_words,
    unique_per_row,
)
from repro.kernels.l2dist import ref as l2ref
from repro.kernels.l2dist.ops import gather_l2_tiled

from conftest import recall_at_k


@pytest.fixture(scope="module")
def graph(small_corpus):
    p = BuildParams(max_degree=24, beam_width=48, t=24, iters=3, block=512)
    return build_approx(small_corpus["base"], p)


@pytest.fixture(scope="module")
def emqg(small_corpus):
    p = BuildParams(max_degree=24, beam_width=48, t=24, iters=2, block=512,
                    align_degree=True)
    return build_emqg(small_corpus["base"], p)


def _params(mode: str, beam_width: int) -> SearchParams:
    if mode == "fixed":
        return SearchParams(k=10, l0=48, l_max=48, adaptive=False,
                            max_hops=512, beam_width=beam_width)
    assert mode == "adaptive"
    return SearchParams(k=10, l0=10, l_max=96, alpha=1.5, adaptive=True,
                        max_hops=2048, beam_width=beam_width)


# ---------------------------------------------------------------------------
# W=1 determinism goldens.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["fixed", "adaptive"])
def test_w1_run_to_run_determinism(graph, small_corpus, mode):
    """Greedy best-first (W=1) is a deterministic schedule: two runs must
    agree bit-for-bit on ids and exactly on every counter."""
    q = jnp.asarray(small_corpus["queries"])
    p = _params(mode, beam_width=1)
    r1 = search(graph, q, p)
    r2 = search(graph, q, p)
    assert (np.asarray(r1.ids) == np.asarray(r2.ids)).all()
    np.testing.assert_array_equal(np.asarray(r1.dists), np.asarray(r2.dists))
    for f in ("n_dist_comps", "n_encounters", "n_hops", "final_l"):
        np.testing.assert_array_equal(np.asarray(getattr(r1, f)),
                                      np.asarray(getattr(r2, f)))


@pytest.mark.parametrize("mode", ["fixed", "adaptive"])
def test_w1_backend_self_parity(graph, small_corpus, mode):
    """The jnp and Pallas distance backends drive the identical schedule:
    same ids, same hop counts, distances equal to kernel tolerance."""
    q = jnp.asarray(small_corpus["queries"][:16])
    p = _params(mode, beam_width=1)
    if mode == "adaptive":     # keep interpret-mode Pallas inside CI budget
        p = SearchParams(**{**p.__dict__, "l_max": 32, "max_hops": 256})
    r_jnp = search(graph, q, p, backend="jnp")
    for backend in ("kernel", "kernel_tiled"):
        r_k = search(graph, q, p, backend=backend)
        assert (np.asarray(r_jnp.ids) == np.asarray(r_k.ids)).all(), backend
        np.testing.assert_array_equal(np.asarray(r_jnp.n_hops),
                                      np.asarray(r_k.n_hops))
        np.testing.assert_allclose(np.asarray(r_jnp.dists),
                                   np.asarray(r_k.dists), rtol=1e-4,
                                   atol=1e-4)


def test_probing_run_to_run_determinism(emqg, small_corpus):
    q = jnp.asarray(small_corpus["queries"])
    p = _params("fixed", beam_width=1)
    r1 = probing_search(emqg, q, p)
    r2 = probing_search(emqg, q, p)
    assert (np.asarray(r1.ids) == np.asarray(r2.ids)).all()
    np.testing.assert_array_equal(np.asarray(r1.n_encounters),
                                  np.asarray(r2.n_encounters))


# ---------------------------------------------------------------------------
# Counter invariants (n_encounters monotonicity).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["fixed", "adaptive"])
def test_encounters_dominate_dist_evals(graph, small_corpus, mode):
    """Encounters are pre-dedup, distance evals post-dedup: per query,
    ``n_encounters ≥ n_dist_comps`` always (the bitset can only remove)."""
    q = jnp.asarray(small_corpus["queries"])
    r = search(graph, q, _params(mode, beam_width=1))
    assert (np.asarray(r.n_encounters) >= np.asarray(r.n_dist_comps)).all()


def test_encounters_monotone_in_beam_width(graph, small_corpus):
    """Wider frontiers do speculative expansions: mean encounters must be
    weakly increasing in W (per-query counts may reorder, the aggregate
    work metric may not shrink)."""
    q = jnp.asarray(small_corpus["queries"])
    means = []
    for w in (1, 2, 4, 8):
        r = search(graph, q, _params("adaptive", beam_width=w))
        means.append(float(np.mean(np.asarray(r.n_encounters))))
    for lo, hi in zip(means, means[1:]):
        assert hi >= lo * 0.98, means


def test_encounters_monotone_in_alpha(graph, small_corpus):
    """Larger α ⇒ stricter stop rule ⇒ weakly more encounters (Alg. 3)."""
    q = jnp.asarray(small_corpus["queries"])
    means = []
    for alpha in (1.0, 1.2, 1.5):
        p = SearchParams(k=10, l0=10, l_max=96, alpha=alpha, adaptive=True,
                         max_hops=2048, beam_width=1)
        r = search(graph, q, p)
        means.append(float(np.mean(np.asarray(r.n_encounters))))
    assert means[0] <= means[1] <= means[2], means


def test_probing_encounters_dominate(emqg, small_corpus):
    q = jnp.asarray(small_corpus["queries"])
    r = probing_search(emqg, q, _params("fixed", beam_width=1))
    assert (np.asarray(r.n_encounters)
            >= np.asarray(r.n_dist_comps)).all()


# ---------------------------------------------------------------------------
# Engine options.
# ---------------------------------------------------------------------------

def test_beam_width_sweep_recall(graph, small_corpus):
    q = jnp.asarray(small_corpus["queries"])
    for w in (1, 2, 4, 8):
        r = search(graph, q, _params("adaptive", beam_width=w))
        assert recall_at_k(r.ids, small_corpus["gt_i"], 10) > 0.85, w


def test_beam_width_zero_rejected(graph, emqg, small_corpus):
    q = jnp.asarray(small_corpus["queries"][:2])
    p = SearchParams(k=3, l0=8, l_max=16, beam_width=0)
    with pytest.raises(ValueError, match="beam_width"):
        search(graph, q, p)
    with pytest.raises(ValueError, match="beam_width"):
        probing_search(emqg, q, p)


def test_faithful_prune_composes_with_beam_options(graph, small_corpus):
    """faithful_prune runs on the batch engine and composes with any
    beam_width and backend — no delegation, no rejection, no warning."""
    import warnings

    q = jnp.asarray(small_corpus["queries"])
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        for w, backend in ((1, "jnp"), (4, "jnp"), (2, "kernel_tiled")):
            p = SearchParams(k=10, l0=10, l_max=48, alpha=1.3, adaptive=True,
                             max_hops=512, beam_width=w)
            qq = q if backend == "jnp" else q[:8]
            r = search(graph, qq, p, faithful_prune=True, backend=backend)
            assert np.isfinite(np.asarray(r.dists)).all(), (w, backend)
    r1 = search(graph, q, SearchParams(k=10, l0=10, l_max=48, alpha=1.3,
                                       adaptive=True, max_hops=512),
                faithful_prune=True)
    assert recall_at_k(r1.ids, small_corpus["gt_i"], 10) > 0.4


def test_faithful_prune_reinsertion_reevaluates(graph, small_corpus):
    """The literal prune clears visited bits of pruned-unexpanded nodes, so
    they can be re-encountered and re-evaluated once ``l`` grows — its
    n_dist may exceed the default engine's (which never re-evaluates)."""
    q = jnp.asarray(small_corpus["queries"])
    p = SearchParams(k=10, l0=10, l_max=96, alpha=1.5, adaptive=True,
                     max_hops=2048, beam_width=1)
    r_def = search(graph, q, p)
    r_fp = search(graph, q, p, faithful_prune=True)
    # both deterministic
    r_fp2 = search(graph, q, p, faithful_prune=True)
    assert (np.asarray(r_fp.ids) == np.asarray(r_fp2.ids)).all()
    # the faithful variant must still produce finite, sorted results
    d = np.asarray(r_fp.dists)
    assert np.isfinite(d).all() and (np.diff(d, axis=1) >= -1e-5).all()
    assert np.asarray(r_def.ids).shape == np.asarray(r_fp.ids).shape


def test_beam_width_clamped_to_buffer(graph, small_corpus):
    """W larger than the candidate buffer must clamp, not crash."""
    q = jnp.asarray(small_corpus["queries"][:2])
    wide = SearchParams(k=3, l0=4, l_max=4, beam_width=64)
    narrow = SearchParams(k=3, l0=4, l_max=4, beam_width=5)  # == l_max+1
    r_wide = search(graph, q, wide)
    r_narrow = search(graph, q, narrow)
    assert (np.asarray(r_wide.ids) == np.asarray(r_narrow.ids)).all()


# ---------------------------------------------------------------------------
# Visited bitset.
# ---------------------------------------------------------------------------

def test_bitset_basic():
    bits = bitset_make(2, 100)
    assert bits.shape == (2, bitset_words(100))
    ids = jnp.asarray([[0, 31, 32, 99], [5, 64, -1, 5]], jnp.int32)
    # duplicate 5 in row 1 → dedup before set (the engine invariant)
    uniq = unique_per_row(ids, ids >= 0)
    bits = bitset_set(bits, uniq)
    probe = jnp.asarray([[0, 31, 32, 99, 1, 33], [5, 64, 0, 6, 99, -1]],
                        jnp.int32)
    got = np.asarray(bitset_test(bits, probe))
    assert got.tolist() == [[True, True, True, True, False, False],
                            [True, True, False, False, False, False]]


def test_bitset_invalid_ids_noop():
    bits = bitset_make(1, 64)
    bits2 = bitset_set(bits, jnp.asarray([[-1, -1]], jnp.int32))
    assert (np.asarray(bits2) == 0).all()
    assert not np.asarray(
        bitset_test(bits2, jnp.asarray([[-1]], jnp.int32)))[0, 0]


def test_bitset_randomized_vs_python_set():
    rng = np.random.default_rng(0)
    n, rounds = 257, 6
    bits = bitset_make(1, n)
    seen = set()
    for _ in range(rounds):
        batch = rng.integers(0, n, size=(1, 16)).astype(np.int32)
        fresh_np = np.asarray(
            [[int(v) not in seen for v in batch[0]]])
        got = ~np.asarray(bitset_test(bits, jnp.asarray(batch)))
        assert (got == fresh_np).all()
        uniq = unique_per_row(jnp.asarray(batch), jnp.asarray(fresh_np))
        bits = bitset_set(bits, uniq)
        seen.update(int(v) for v in batch[0])


def test_unique_per_row():
    ids = jnp.asarray([[7, 3, 7, 3, 9, -1], [1, 1, 1, 1, 1, 1]], jnp.int32)
    fresh = ids >= 0
    out = np.asarray(unique_per_row(ids, fresh))
    assert sorted(v for v in out[0] if v >= 0) == [3, 7, 9]
    assert sorted(v for v in out[1] if v >= 0) == [1]
    # valid prefix is sorted ascending, invalid tail is -1
    row = out[0]
    valid = row[row >= 0]
    assert (np.diff(valid) > 0).all()


# ---------------------------------------------------------------------------
# Tiled gather kernel.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,M,d,R", [(2, 16, 24, 8), (4, 30, 128, 8),
                                     (1, 7, 65, 4), (3, 24, 33, 8)])
def test_gather_l2_tiled_vs_ref(B, M, d, R):
    rng = np.random.default_rng(B * 100 + M + d)
    n = 200
    base = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    ids = rng.integers(0, n, (B, M)).astype(np.int32)
    ids[0, 0] = -1                      # INVALID handling
    ids = jnp.asarray(ids)
    qs = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    out = np.asarray(gather_l2_tiled(base, ids, qs, block_rows=R))
    expect = np.asarray(l2ref.gather_l2_ref(base, jnp.maximum(ids, 0), qs))
    assert np.isinf(out[0, 0])
    mask = np.asarray(ids) >= 0
    np.testing.assert_allclose(out[mask], expect[mask], rtol=1e-4, atol=1e-3)


def test_gather_l2_tiled_matches_single_row():
    from repro.kernels.l2dist.ops import gather_l2

    rng = np.random.default_rng(11)
    base = jnp.asarray(rng.normal(size=(64, 48)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 64, (4, 24)).astype(np.int32))
    qs = jnp.asarray(rng.normal(size=(4, 48)).astype(np.float32))
    a = np.asarray(gather_l2(base, ids, qs))
    b = np.asarray(gather_l2_tiled(base, ids, qs))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Serving layer.
# ---------------------------------------------------------------------------

def test_server_backends_agree(graph, small_corpus):
    """W=1 determinism holds through the serving layer: the same queries
    served under different distance backends return identical ids."""
    from repro.serve.ann_server import AnnServer

    params = SearchParams(k=10, l0=10, l_max=32, alpha=1.5, adaptive=True,
                          max_hops=256, beam_width=1)
    out = {}
    for backend in ("jnp", "kernel_tiled"):
        srv = AnnServer(graph, params, max_batch=8, buckets=(8,),
                        backend=backend)
        srv.submit_many(small_corpus["queries"][:8])
        out[backend] = srv.drain()
    for (ids_a, d_a), (ids_b, d_b) in zip(out["jnp"], out["kernel_tiled"]):
        assert (ids_a == ids_b).all()
        np.testing.assert_allclose(d_a, d_b, rtol=1e-4, atol=1e-4)


def test_server_rejects_unknown_engine(graph):
    from repro.serve.ann_server import AnnServer

    params = SearchParams(k=5, l0=8, l_max=16)
    with pytest.raises(ValueError, match="unknown engine"):
        AnnServer(graph, params, engine="legacy")
