"""Beam-engine parity vs the legacy per-query engines, plus unit tests for
the packed visited bitset and the tiled gather+L2 kernel.

Parity contract: at ``beam_width=1`` the batch-level lock-step engine expands
nodes in the identical order to the seed per-query engine and must return
*identical* top-k ids and distances in every mode (fixed-l greedy, adaptive-α,
probing).  At ``beam_width>1`` the expansion schedule is reordered (W nodes
per hop), which monotonic-graph convergence tolerates — results may differ on
individual queries, so the suite asserts recall parity instead.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BuildParams,
    SearchParams,
    build_approx,
    build_emqg,
    legacy_probing_search,
    legacy_search,
    probing_search,
    search,
)
from repro.core.bitset import (
    bitset_make,
    bitset_set,
    bitset_test,
    bitset_words,
    unique_per_row,
)
from repro.kernels.l2dist import ref as l2ref
from repro.kernels.l2dist.ops import gather_l2_tiled

from conftest import recall_at_k


@pytest.fixture(scope="module")
def graph(small_corpus):
    p = BuildParams(max_degree=24, beam_width=48, t=24, iters=3, block=512)
    return build_approx(small_corpus["base"], p)


@pytest.fixture(scope="module")
def emqg(small_corpus):
    p = BuildParams(max_degree=24, beam_width=48, t=24, iters=2, block=512,
                    align_degree=True)
    return build_emqg(small_corpus["base"], p)


def _params(mode: str, beam_width: int) -> SearchParams:
    if mode == "fixed":
        return SearchParams(k=10, l0=48, l_max=48, adaptive=False,
                            max_hops=512, beam_width=beam_width)
    assert mode == "adaptive"
    return SearchParams(k=10, l0=10, l_max=96, alpha=1.5, adaptive=True,
                        max_hops=2048, beam_width=beam_width)


# ---------------------------------------------------------------------------
# Engine parity.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["fixed", "adaptive"])
def test_graph_parity_w1(graph, small_corpus, mode):
    q = jnp.asarray(small_corpus["queries"])
    p = _params(mode, beam_width=1)
    r_beam = search(graph, q, p)
    r_legacy = legacy_search(graph, q, p)
    assert (np.asarray(r_beam.ids) == np.asarray(r_legacy.ids)).all()
    np.testing.assert_allclose(np.asarray(r_beam.dists),
                               np.asarray(r_legacy.dists), rtol=1e-6)
    # identical expansion schedule ⇒ identical hop counts
    assert (np.asarray(r_beam.n_hops) == np.asarray(r_legacy.n_hops)).all()
    assert (np.asarray(r_beam.final_l) == np.asarray(r_legacy.final_l)).all()


@pytest.mark.parametrize("mode", ["fixed", "adaptive"])
def test_probing_parity_w1(emqg, small_corpus, mode):
    q = jnp.asarray(small_corpus["queries"])
    p = _params(mode, beam_width=1)
    if mode == "adaptive":
        p = SearchParams(**{**p.__dict__, "max_hops": 4096})
    r_beam = probing_search(emqg, q, p)
    r_legacy = legacy_probing_search(emqg, q, p)
    assert (np.asarray(r_beam.ids) == np.asarray(r_legacy.ids)).all()
    np.testing.assert_allclose(np.asarray(r_beam.dists),
                               np.asarray(r_legacy.dists), rtol=1e-6)


@pytest.mark.parametrize("mode", ["fixed", "adaptive"])
def test_graph_recall_parity_w4(graph, small_corpus, mode):
    """W=4 reorders expansions; quality must hold even where ids differ."""
    q = jnp.asarray(small_corpus["queries"])
    r_beam = search(graph, q, _params(mode, beam_width=4))
    r_legacy = legacy_search(graph, q, _params(mode, beam_width=1))
    rec_beam = recall_at_k(r_beam.ids, small_corpus["gt_i"], 10)
    rec_legacy = recall_at_k(r_legacy.ids, small_corpus["gt_i"], 10)
    assert rec_beam >= rec_legacy - 0.03
    # per-query k-th distance can't degrade materially either
    d_beam = np.asarray(r_beam.dists)[:, -1]
    d_legacy = np.asarray(r_legacy.dists)[:, -1]
    assert np.mean(d_beam <= d_legacy * 1.05) > 0.95


def test_probing_recall_parity_w4(emqg, small_corpus):
    q = jnp.asarray(small_corpus["queries"])
    p4 = SearchParams(k=10, l0=10, l_max=96, alpha=1.5, adaptive=True,
                      max_hops=4096, beam_width=4)
    p1 = SearchParams(**{**p4.__dict__, "beam_width": 1})
    r_beam = probing_search(emqg, q, p4)
    r_legacy = legacy_probing_search(emqg, q, p1)
    rec_beam = recall_at_k(r_beam.ids, small_corpus["gt_i"], 10)
    rec_legacy = recall_at_k(r_legacy.ids, small_corpus["gt_i"], 10)
    assert rec_beam >= rec_legacy - 0.03


def test_beam_fewer_dist_evals(graph, small_corpus):
    """The bitset dedup strictly dominates the ring buffer: identical results
    with fewer exact distance evaluations."""
    q = jnp.asarray(small_corpus["queries"])
    p = _params("adaptive", beam_width=1)
    r_beam = search(graph, q, p)
    r_legacy = legacy_search(graph, q, p)
    assert (np.asarray(r_beam.ids) == np.asarray(r_legacy.ids)).all()
    assert (np.asarray(r_beam.n_dist_comps)
            <= np.asarray(r_legacy.n_dist_comps)).all()
    assert (np.asarray(r_beam.n_dist_comps).mean()
            < np.asarray(r_legacy.n_dist_comps).mean())


@pytest.mark.parametrize("mode", ["fixed", "adaptive"])
def test_encounter_parity_w1(graph, small_corpus, mode):
    """``n_encounters`` counts candidate *encounters* (valid neighbor slots
    seen, pre-dedup) — unlike ``n_dist_comps`` it is independent of how
    much the visited-set dedup saves, so at W=1 (identical expansion
    schedules) the two engines must agree exactly.  This is the Exp-5
    work metric; ``n_dist_comps`` alone undercounted beam-engine work
    because the bitset dedup is stronger than the legacy ring buffer."""
    q = jnp.asarray(small_corpus["queries"])
    p = _params(mode, beam_width=1)
    r_beam = search(graph, q, p)
    r_legacy = legacy_search(graph, q, p)
    np.testing.assert_array_equal(np.asarray(r_beam.n_encounters),
                                  np.asarray(r_legacy.n_encounters))
    # encounters are pre-dedup ⇒ can never be fewer than exact evaluations
    assert (np.asarray(r_beam.n_encounters)
            >= np.asarray(r_beam.n_dist_comps)).all()
    assert (np.asarray(r_legacy.n_encounters)
            >= np.asarray(r_legacy.n_dist_comps)).all()


def test_probing_encounter_parity_w1(emqg, small_corpus):
    q = jnp.asarray(small_corpus["queries"])
    p = _params("fixed", beam_width=1)
    r_beam = probing_search(emqg, q, p)
    r_legacy = legacy_probing_search(emqg, q, p)
    np.testing.assert_array_equal(np.asarray(r_beam.n_encounters),
                                  np.asarray(r_legacy.n_encounters))


def test_kernel_backends_match_jnp(graph, small_corpus):
    q = jnp.asarray(small_corpus["queries"][:8])
    p = SearchParams(k=5, l0=16, l_max=16, adaptive=False, max_hops=64,
                     beam_width=2)
    r_jnp = search(graph, q, p, backend="jnp")
    for backend in ("kernel", "kernel_tiled"):
        r_k = search(graph, q, p, backend=backend)
        assert (np.asarray(r_jnp.ids) == np.asarray(r_k.ids)).all(), backend
        np.testing.assert_allclose(np.asarray(r_jnp.dists),
                                   np.asarray(r_k.dists), rtol=1e-4,
                                   atol=1e-4)


def test_beam_width_sweep_recall(graph, small_corpus):
    q = jnp.asarray(small_corpus["queries"])
    for w in (1, 2, 4, 8):
        r = search(graph, q, _params("adaptive", beam_width=w))
        assert recall_at_k(r.ids, small_corpus["gt_i"], 10) > 0.85, w


def test_beam_width_zero_rejected(graph, emqg, small_corpus):
    q = jnp.asarray(small_corpus["queries"][:2])
    p = SearchParams(k=3, l0=8, l_max=16, beam_width=0)
    with pytest.raises(ValueError, match="beam_width"):
        search(graph, q, p)
    with pytest.raises(ValueError, match="beam_width"):
        probing_search(emqg, q, p)


def test_faithful_prune_rejects_beam_options(graph, small_corpus):
    """faithful_prune delegates to the legacy engine; non-default beam
    options must be refused, not silently dropped."""
    q = jnp.asarray(small_corpus["queries"][:2])
    p = SearchParams(k=3, l0=8, l_max=16, beam_width=4)
    with pytest.raises(ValueError, match="faithful_prune"):
        search(graph, q, p, faithful_prune=True)
    p1 = SearchParams(k=3, l0=8, l_max=16)
    with pytest.raises(ValueError, match="faithful_prune"):
        search(graph, q, p1, faithful_prune=True, backend="jnp")


def test_beam_width_clamped_to_buffer(graph, small_corpus):
    """W larger than the candidate buffer must clamp, not crash."""
    q = jnp.asarray(small_corpus["queries"][:2])
    wide = SearchParams(k=3, l0=4, l_max=4, beam_width=64)
    narrow = SearchParams(k=3, l0=4, l_max=4, beam_width=5)  # == l_max+1
    r_wide = search(graph, q, wide)
    r_narrow = search(graph, q, narrow)
    assert (np.asarray(r_wide.ids) == np.asarray(r_narrow.ids)).all()


# ---------------------------------------------------------------------------
# Visited bitset.
# ---------------------------------------------------------------------------

def test_bitset_basic():
    bits = bitset_make(2, 100)
    assert bits.shape == (2, bitset_words(100))
    ids = jnp.asarray([[0, 31, 32, 99], [5, 64, -1, 5]], jnp.int32)
    # duplicate 5 in row 1 → dedup before set (the engine invariant)
    uniq = unique_per_row(ids, ids >= 0)
    bits = bitset_set(bits, uniq)
    probe = jnp.asarray([[0, 31, 32, 99, 1, 33], [5, 64, 0, 6, 99, -1]],
                        jnp.int32)
    got = np.asarray(bitset_test(bits, probe))
    assert got.tolist() == [[True, True, True, True, False, False],
                            [True, True, False, False, False, False]]


def test_bitset_invalid_ids_noop():
    bits = bitset_make(1, 64)
    bits2 = bitset_set(bits, jnp.asarray([[-1, -1]], jnp.int32))
    assert (np.asarray(bits2) == 0).all()
    assert not np.asarray(
        bitset_test(bits2, jnp.asarray([[-1]], jnp.int32)))[0, 0]


def test_bitset_randomized_vs_python_set():
    rng = np.random.default_rng(0)
    n, rounds = 257, 6
    bits = bitset_make(1, n)
    seen = set()
    for _ in range(rounds):
        batch = rng.integers(0, n, size=(1, 16)).astype(np.int32)
        fresh_np = np.asarray(
            [[int(v) not in seen for v in batch[0]]])
        got = ~np.asarray(bitset_test(bits, jnp.asarray(batch)))
        assert (got == fresh_np).all()
        uniq = unique_per_row(jnp.asarray(batch), jnp.asarray(fresh_np))
        bits = bitset_set(bits, uniq)
        seen.update(int(v) for v in batch[0])


def test_unique_per_row():
    ids = jnp.asarray([[7, 3, 7, 3, 9, -1], [1, 1, 1, 1, 1, 1]], jnp.int32)
    fresh = ids >= 0
    out = np.asarray(unique_per_row(ids, fresh))
    assert sorted(v for v in out[0] if v >= 0) == [3, 7, 9]
    assert sorted(v for v in out[1] if v >= 0) == [1]
    # valid prefix is sorted ascending, invalid tail is -1
    row = out[0]
    valid = row[row >= 0]
    assert (np.diff(valid) > 0).all()


# ---------------------------------------------------------------------------
# Tiled gather kernel.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,M,d,R", [(2, 16, 24, 8), (4, 30, 128, 8),
                                     (1, 7, 65, 4), (3, 24, 33, 8)])
def test_gather_l2_tiled_vs_ref(B, M, d, R):
    rng = np.random.default_rng(B * 100 + M + d)
    n = 200
    base = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    ids = rng.integers(0, n, (B, M)).astype(np.int32)
    ids[0, 0] = -1                      # INVALID handling
    ids = jnp.asarray(ids)
    qs = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    out = np.asarray(gather_l2_tiled(base, ids, qs, block_rows=R))
    expect = np.asarray(l2ref.gather_l2_ref(base, jnp.maximum(ids, 0), qs))
    assert np.isinf(out[0, 0])
    mask = np.asarray(ids) >= 0
    np.testing.assert_allclose(out[mask], expect[mask], rtol=1e-4, atol=1e-3)


def test_gather_l2_tiled_matches_single_row():
    from repro.kernels.l2dist.ops import gather_l2

    rng = np.random.default_rng(11)
    base = jnp.asarray(rng.normal(size=(64, 48)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 64, (4, 24)).astype(np.int32))
    qs = jnp.asarray(rng.normal(size=(4, 48)).astype(np.float32))
    a = np.asarray(gather_l2(base, ids, qs))
    b = np.asarray(gather_l2_tiled(base, ids, qs))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Serving layer A/B.
# ---------------------------------------------------------------------------

def test_server_engines_agree(graph, small_corpus):
    from repro.serve.ann_server import AnnServer

    params = SearchParams(k=10, l0=10, l_max=64, alpha=1.5, adaptive=True,
                          max_hops=1024, beam_width=1)
    out = {}
    for engine in ("beam", "legacy"):
        srv = AnnServer(graph, params, max_batch=32, buckets=(8, 32),
                        engine=engine)
        srv.submit_many(small_corpus["queries"][:20])
        out[engine] = srv.drain()
    for (ids_b, d_b), (ids_l, d_l) in zip(out["beam"], out["legacy"]):
        assert (ids_b == ids_l).all()
        np.testing.assert_allclose(d_b, d_l, rtol=1e-6)
