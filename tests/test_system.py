"""End-to-end behaviour of the paper's system: build → serve → validate the
error-bounded contract, baselines included (the 'does the whole thing hang
together' test)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BuildParams,
    SearchParams,
    baselines,
    build_approx,
    build_emqg,
    error_bounded_probing_search,
    error_bounded_search,
    greedy_search,
)
from repro.core.distances import brute_force_knn
from repro.data import clustered_vectors

from conftest import recall_at_k


@pytest.fixture(scope="module")
def corpus():
    # d=48 gives RaBitQ its O(1/√d) headroom; moderate cluster overlap
    # (scale 0.6) matches the paper's dataset LID range
    base = clustered_vectors(1500, 48, 24, seed=5, scale=0.6)
    queries = clustered_vectors(48, 48, 24, seed=6, scale=0.6)
    gt_d, gt_i = brute_force_knn(queries, base, 10)
    return base, queries, gt_d, gt_i


def test_full_pipeline_emg(corpus):
    base, queries, gt_d, gt_i = corpus
    g = build_approx(base, BuildParams(max_degree=24, beam_width=64, t=40,
                                       iters=3, block=512))
    res = error_bounded_search(g, jnp.asarray(queries), k=10, alpha=2.0,
                               l_max=192)
    rec = recall_at_k(res.ids, gt_i, 10)
    assert rec > 0.9
    # relative distance error small in aggregate (Exp-5's metric)
    dists = np.asarray(res.dists)
    rde = (dists - gt_d) / np.maximum(gt_d, 1e-9)
    assert rde.mean() < 0.02
    assert (rde >= -1e-4).all()        # can never beat the ground truth


def test_full_pipeline_emqg(corpus):
    base, queries, gt_d, gt_i = corpus
    idx = build_emqg(base, BuildParams(max_degree=24, beam_width=64, t=40,
                                       iters=2, block=512, align_degree=True))
    res = error_bounded_probing_search(idx, jnp.asarray(queries), k=10,
                                       alpha=2.0, l_max=192)
    assert recall_at_k(res.ids, gt_i, 10) > 0.75
    # quantized search must do most distance work in the approximate tier
    assert (np.asarray(res.n_approx_comps) >
            np.asarray(res.n_dist_comps)).mean() > 0.9


@pytest.mark.parametrize("builder", ["nsg", "vamana", "tau_mg"])
def test_baseline_builders_serve(corpus, builder):
    base, queries, gt_d, gt_i = corpus
    g = baselines.BUILDERS[builder](base, max_degree=24, beam_width=48)
    res = greedy_search(g, jnp.asarray(queries), k=10, l=64)
    rec = recall_at_k(res.ids, gt_i, 10)
    assert rec > 0.6, (builder, rec)


def test_knn_graph_lacks_navigability(corpus):
    """Motivating observation: a plain kNN graph has no inter-cluster
    navigability — greedy search from the medoid strands in one cluster.
    The occlusion-rule graphs exist precisely to fix this."""
    base, queries, gt_d, gt_i = corpus
    g_knn = baselines.build_knn_graph(base, k=24)
    g_emg = __import__("repro.core", fromlist=["build_approx"]).build_approx(
        base, BuildParams(max_degree=24, beam_width=64, t=40, iters=2,
                          block=512))
    r_knn = recall_at_k(greedy_search(g_knn, jnp.asarray(queries), k=10,
                                      l=96).ids, gt_i, 10)
    r_emg = recall_at_k(greedy_search(g_emg, jnp.asarray(queries), k=10,
                                      l=96).ids, gt_i, 10)
    assert r_emg > r_knn + 0.3


def test_nsw_baseline(corpus):
    base, queries, gt_d, gt_i = corpus
    g = baselines.build_nsw(base, max_degree=24, ef=48, wave=256)
    res = greedy_search(g, jnp.asarray(queries), k=10, l=64)
    assert recall_at_k(res.ids, gt_i, 10) > 0.45
