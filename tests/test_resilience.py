"""Resilience layer: admission control, degradation ladder, deadlines,
fault containment (retry / circuit breaker / tier fallback), and the serve
regressions (bucket clamp, clock-consistent latency accounting).

The fault-injection tests carry ``@pytest.mark.faults`` so CI can run the
suite explicitly (and under a pytest-timeout ceiling: an injected hang must
fail fast, not wedge the job)."""

import math
import time

import numpy as np
import pytest

import dataclasses

from repro.core import SearchParams, build_exact, search
from repro.serve import (
    AnnServer,
    CircuitBreaker,
    DegradationLadder,
    ResilienceConfig,
    ResilientAnnServer,
    validate_query,
)
from repro.serve.resilience import default_tiers
from repro.testing import FaultPlan, KernelFault, inject_search_faults

import jax.numpy as jnp


@pytest.fixture(scope="module")
def tiny():
    rng = np.random.default_rng(7)
    base = rng.normal(size=(300, 16)).astype(np.float32)
    with pytest.warns(UserWarning):          # degree cap on a dense corpus
        graph = build_exact(base, delta=0.15, max_degree=12)
    queries = rng.normal(size=(64, 16)).astype(np.float32)
    return {"graph": graph, "queries": queries}


PARAMS = SearchParams(k=5, l0=8, l_max=64, alpha=1.4, adaptive=True,
                      max_hops=512, beam_width=4)


def fast_cfg(**kw):
    kw.setdefault("backoff_s", 0.0)
    return ResilienceConfig(**kw)


# ---------------------------------------------------------------------------
# Serve regressions (satellites).
# ---------------------------------------------------------------------------


def test_drain_bucket_clamp_regression(tiny):
    """max_batch above the largest bucket used to compute a negative pad and
    crash np.repeat; the batch must be served unpadded instead."""
    srv = AnnServer(tiny["graph"], PARAMS, max_batch=100, buckets=(8, 32, 64))
    srv.submit_many(np.concatenate([tiny["queries"], tiny["queries"][:36]]))
    out = srv.drain()                       # first take: 100 > largest bucket
    assert len(out) == 100
    assert srv.stats.n_batches == 1


def test_replay_trace_latency_uses_wall_clock(tiny):
    """Synthetic arrival timestamps (trace clock) must not leak into the
    wall-clock latency accounting — the seed mixed the two and reported
    nonsense (≈ wall_time - trace_time) latencies."""
    srv = AnnServer(tiny["graph"], PARAMS, max_batch=32, buckets=(32,))
    # an absurd trace clock: arrivals billions of seconds in the past/future
    srv.submit_many(tiny["queries"][:32],
                    arrival_ts=np.linspace(-2e9, 2e9, 32))
    out = srv.drain()
    assert len(out) == 32
    assert 0.0 <= srv.stats.mean_latency_s < 120.0
    assert 0.0 <= srv.stats.max_latency_s < 120.0


# ---------------------------------------------------------------------------
# Per-request validation.
# ---------------------------------------------------------------------------


def test_validate_query_reasons():
    assert validate_query(np.zeros(16, np.float32), 16) is None
    assert validate_query(np.zeros(16, np.int32), 16) is None  # castable
    assert "dim" in validate_query(np.zeros(7, np.float32), 16)
    assert "rank-1" in validate_query(np.zeros((2, 16), np.float32), 16)
    assert "non-finite" in validate_query(
        np.array([np.nan] * 16, np.float32), 16)
    assert "non-finite" in validate_query(
        np.array([np.inf] + [0.0] * 15, np.float32), 16)
    assert validate_query(["a"] * 16, 16) is not None


def test_nan_query_rejected_per_request_not_per_batch(tiny):
    """One bad query must cost *itself* the response, not its batch."""
    srv = ResilientAnnServer(tiny["graph"], PARAMS, config=fast_cfg(),
                             max_batch=8, buckets=(8,))
    good = tiny["queries"][:6]
    srv.submit(good[0])
    srv.submit(np.array([np.nan] * 16, np.float32))     # NaN
    srv.submit(good[1])
    srv.submit(np.zeros(7, np.float32))                 # wrong dim
    srv.submit(np.array([np.inf] * 16, np.float32))     # Inf
    for q in good[2:]:
        srv.submit(q)
    rs = srv.drain()
    assert len(rs) == 9
    statuses = [r.status for r in rs]
    assert statuses.count("rejected") == 3
    assert statuses.count("ok") == 6
    assert srv.stats.n_rejected == 3 and srv.stats.n_requests == 6
    # the good queries got real results, identical to an unfaulted server
    ref = search(tiny["graph"], jnp.asarray(good), PARAMS)
    ok = [r for r in rs if r.ok]
    for i, r in enumerate(ok):
        assert r.ids.shape == (PARAMS.k,)
        np.testing.assert_array_equal(r.ids, np.asarray(ref.ids)[i])


# ---------------------------------------------------------------------------
# Degradation ladder.
# ---------------------------------------------------------------------------


def test_ladder_rungs_monotone():
    lad = DegradationLadder(PARAMS, delta=0.2, n_rungs=4)
    lmaxs = [lad.params(r).l_max for r in range(4)]
    beams = [lad.params(r).beam_width for r in range(4)]
    alphas = [lad.params(r).alpha for r in range(4)]
    bounds = [lad.delta_bound(r) for r in range(4)]
    assert lmaxs == sorted(lmaxs, reverse=True) and lmaxs[-1] >= PARAMS.k
    assert beams == sorted(beams, reverse=True) and beams[-1] >= 1
    assert alphas == sorted(alphas, reverse=True) and alphas[-1] >= 1.0
    # relaxing α loosens (grows) the reported approximation factor, but it
    # stays finite and never exceeds the pure-monotonicity bound 1/δ
    assert bounds == sorted(bounds)
    assert all(math.isfinite(b) and b <= 1 / 0.2 + 1e-9 for b in bounds)
    # unknown construction δ → honest infinite bound
    assert math.isinf(DegradationLadder(PARAMS, delta=0.0).delta_bound(0))


def test_overload_engages_ladder_with_finite_bounds(tiny):
    """Under injected overload the server keeps accepting and serving, and
    every degraded response reports a finite δ error bound."""
    srv = ResilientAnnServer(
        tiny["graph"], PARAMS,
        config=fast_cfg(degrade_depth=8, recover_depth=2, n_rungs=4),
        max_batch=8, buckets=(8,))
    reps = np.repeat(tiny["queries"], 2, axis=0)        # 128-deep burst
    srv.submit_many(reps)
    rs = srv.drain()
    assert len(rs) == len(reps)
    assert all(r.ok for r in rs)
    assert srv.stats.n_degraded > 0
    degraded = [r for r in rs if r.rung > 0]
    assert degraded, "overload never engaged the ladder"
    assert all(math.isfinite(r.delta_bound) for r in degraded)
    assert all(r.delta_bound >= 1.0 for r in degraded)
    # degraded responses still return k well-formed neighbors
    for r in degraded[:5]:
        assert r.ids.shape == (PARAMS.k,)
        assert (np.diff(r.dists) >= -1e-5).all()


def test_ladder_recovers_when_queue_drains(tiny):
    srv = ResilientAnnServer(
        tiny["graph"], PARAMS,
        config=fast_cfg(degrade_depth=8, recover_depth=4, n_rungs=3),
        max_batch=8, buckets=(8,))
    srv.submit_many(np.repeat(tiny["queries"], 2, axis=0))
    srv.drain()
    peak = srv.rung
    assert peak > 0
    for _ in range(peak + 1):                # light traffic → climb back up
        srv.submit_many(tiny["queries"][:2])
        rs = srv.drain()
    assert srv.rung == 0
    assert rs[-1].rung <= 1                  # last light batch near full quality


# ---------------------------------------------------------------------------
# Admission control, deadlines.
# ---------------------------------------------------------------------------


def test_queue_full_sheds_without_exception(tiny):
    srv = ResilientAnnServer(tiny["graph"], PARAMS,
                             config=fast_cfg(max_queue=4),
                             max_batch=8, buckets=(8,))
    terminal = [srv.submit(q) for q in tiny["queries"][:10]]
    assert sum(t is not None and t.status == "shed" for t in terminal) == 6
    rs = srv.drain()
    assert len(rs) == 10                     # one response per submission
    assert sum(r.status == "shed" for r in rs) == 6
    assert sum(r.ok for r in rs) == 4
    assert srv.stats.n_shed == 6
    # responses come back in submission order
    assert [r.seq for r in rs] == sorted(r.seq for r in rs)


def test_expired_deadline_dropped_at_dispatch(tiny):
    srv = ResilientAnnServer(tiny["graph"], PARAMS,
                             config=fast_cfg(deadline_s=0.0),
                             max_batch=8, buckets=(8,))
    srv.submit_many(tiny["queries"][:8])
    time.sleep(0.01)
    rs = srv.drain()
    assert all(r.status == "deadline" for r in rs)
    assert srv.stats.n_deadline_missed == 8
    assert srv.stats.n_requests == 0         # no search budget burned


@pytest.mark.faults
def test_latency_spike_flags_deadline_missed(tiny):
    srv = ResilientAnnServer(tiny["graph"], PARAMS,
                             config=fast_cfg(deadline_s=0.05),
                             max_batch=8, buckets=(8,))
    with inject_search_faults(srv, FaultPlan(latency_s=0.12)):
        srv.submit_many(tiny["queries"][:8])
        rs = srv.drain()
    assert all(r.ok for r in rs)             # still answered …
    assert all(r.deadline_missed for r in rs)  # … but flagged late
    assert srv.stats.n_deadline_missed == 8


# ---------------------------------------------------------------------------
# Fault containment: retry, breaker, tier fallback.
# ---------------------------------------------------------------------------


@pytest.mark.faults
def test_transient_fault_retried_same_tier(tiny):
    srv = ResilientAnnServer(tiny["graph"], PARAMS, config=fast_cfg(),
                             max_batch=8, buckets=(8,))
    with inject_search_faults(srv, FaultPlan(fail_first=1)) as inj:
        srv.submit_many(tiny["queries"][:8])
        rs = srv.drain()
    assert inj.n_failed == 1
    assert all(r.ok for r in rs)
    assert srv.stats.n_retried == 1
    assert srv.stats.n_fallback == 0
    assert all(r.tier.startswith("beam") for r in rs)


@pytest.mark.faults
def test_persistent_kernel_fault_falls_back_to_single_beam(tiny):
    """A fault that kills every wide-beam configuration (e.g. a broken
    multi-row gather kernel) must walk the breaker down to the last-resort
    ``(beam, jnp, W=1)`` tier — greedy best-first on the production engine,
    with results identical to calling it directly, and zero failed
    requests.  There is no tier below it — W=1 on the batch engine is the
    floor of the chain."""
    srv = ResilientAnnServer(
        tiny["graph"], PARAMS,
        config=fast_cfg(breaker_threshold=2), max_batch=8, buckets=(8,))
    qs = tiny["queries"][:16]
    with inject_search_faults(
            srv, FaultPlan(fail_first=10**6, match_engine="beam",
                           match_min_beam_width=2)) as inj:
        srv.submit_many(qs)
        rs = srv.drain()
    assert inj.n_failed >= 2
    assert all(r.ok for r in rs) and srv.stats.n_failed == 0
    assert srv.stats.n_fallback >= 1
    assert all(r.tier == "beam/jnp/w1" for r in rs)
    ref = search(tiny["graph"], jnp.asarray(qs),
                 dataclasses.replace(srv.ladder.params(srv.rung),
                                     beam_width=1), backend="jnp")
    np.testing.assert_array_equal(
        np.stack([r.ids for r in rs]), np.asarray(ref.ids))


@pytest.mark.faults
def test_breaker_ladder_bottoms_out_at_beam_jnp_w1(tiny):
    """The tier log of a persistent-fault walk must end at the terminal
    ``(beam, jnp, 1)`` tier and never mention any other engine — there is
    no engine below the beam engine to reach for."""
    srv = ResilientAnnServer(
        tiny["graph"], PARAMS,
        config=fast_cfg(breaker_threshold=2), max_batch=8, buckets=(8,))
    with inject_search_faults(
            srv, FaultPlan(fail_first=10**6, match_engine="beam",
                           match_min_beam_width=2)) as inj:
        srv.submit_many(tiny["queries"][:16])
        rs = srv.drain()
    assert all(r.ok for r in rs)
    assert inj.tier_log[-1] == ("beam", "jnp", 1)
    assert {t[0] for t in inj.tier_log} == {"beam"}
    # the walked ladder is exactly the default chain, in order
    walked = []
    for t in inj.tier_log:
        if t not in walked:
            walked.append(t)
    assert walked == [("beam", "auto", PARAMS.beam_width),
                      ("beam", "jnp", PARAMS.beam_width), ("beam", "jnp", 1)]


@pytest.mark.faults
def test_every_tier_dead_yields_failed_responses_not_a_crash(tiny):
    """Exhausting the whole chain raises cleanly *inside* the containment:
    per-request ``status="failed"``, no crash, and the final attempt was on
    the terminal ``(beam, jnp, 1)`` tier — not some deleted engine."""
    srv = ResilientAnnServer(
        tiny["graph"], PARAMS,
        config=fast_cfg(breaker_threshold=2, max_retries=1),
        max_batch=8, buckets=(8,))
    with inject_search_faults(srv, FaultPlan(fail_first=10**6)) as inj:
        srv.submit_many(tiny["queries"][:8])
        rs = srv.drain()                     # must not raise
    assert all(r.status == "failed" for r in rs)
    assert all("KernelFault" in r.error for r in rs)
    assert srv.stats.n_failed == 8
    assert inj.tier_log[-1] == ("beam", "jnp", 1)
    assert {t[0] for t in inj.tier_log} == {"beam"}


def test_circuit_breaker_half_open_recovery():
    t = [0.0]
    br = CircuitBreaker([("beam", "auto"), ("beam", "jnp")],
                        threshold=2, cooldown_s=10.0, clock=lambda: t[0])
    assert br.current()[0] == 0
    br.record_failure(0)
    assert br.current()[0] == 0              # below threshold: still closed
    br.record_failure(0)
    assert br.current()[0] == 1              # open → fallback tier
    t[0] = 5.0
    assert br.current()[0] == 1              # still cooling down
    t[0] = 11.0
    assert br.current()[0] == 0              # half-open: probe the primary
    br.record_failure(0)                     # probe fails → re-open
    assert br.current()[0] == 1
    t[0] = 25.0
    br.record_success(0)                     # second probe succeeds → closed
    assert br.current()[0] == 0
    assert br.tiers[0].failures == 0


def test_default_tiers_chain():
    """The chain always bottoms out at ``(beam, jnp, 1)`` — greedy
    best-first on the batch engine is the terminal tier for any starting
    engine/backend, and no deleted engine name can reappear."""
    assert default_tiers("beam", "auto") == \
        [("beam", "auto", None), ("beam", "jnp", None), ("beam", "jnp", 1)]
    assert default_tiers("beam", "jnp") == \
        [("beam", "jnp", None), ("beam", "jnp", 1)]
    for engine in ("beam", "probing"):
        for backend in ("auto", "jnp", "kernel", "kernel_tiled"):
            chain = default_tiers(engine, backend)
            assert chain[-1] == ("beam", "jnp", 1)
            assert len(chain) == len(set(chain))      # no duplicate tiers
            assert all(t[0] in ("beam", "probing") for t in chain)
