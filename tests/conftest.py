"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the real
single-CPU device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""

import os

import numpy as np
import pytest

try:
    # CI hypothesis profile: derandomized (fixed seed) with bounded examples
    # so property tests are deterministic and time-boxed; select another
    # profile via HYPOTHESIS_PROFILE.  Absent hypothesis, property tests
    # skip via tests/hypothesis_compat.py and no profile is needed.
    from hypothesis import HealthCheck, settings as _hyp_settings

    _hyp_settings.register_profile(
        "ci", max_examples=25, deadline=None, derandomize=True,
        suppress_health_check=[HealthCheck.too_slow])
    _hyp_settings.register_profile("dev", max_examples=50, deadline=None)
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ModuleNotFoundError:
    pass


def gmm(n, d, k_clusters, seed, scale=0.35):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k_clusters, d))
    asg = rng.integers(0, k_clusters, n)
    return (centers[asg] + scale * rng.normal(size=(n, d))).astype(np.float32)


@pytest.fixture(scope="session")
def small_corpus():
    """Clustered corpus + queries + brute-force ground truth (k=10)."""
    from repro.core.distances import brute_force_knn

    base = gmm(1200, 24, 24, seed=0)
    queries = gmm(64, 24, 24, seed=1)
    gt_d, gt_i = brute_force_knn(queries, base, 10)
    return {"base": base, "queries": queries, "gt_d": gt_d, "gt_i": gt_i}


def recall_at_k(ids, gt_i, k):
    ids = np.asarray(ids)[:, :k]
    return float(np.mean([
        len(set(ids[i].tolist()) & set(gt_i[i, :k].tolist())) / k
        for i in range(ids.shape[0])
    ]))


@pytest.fixture(scope="session")
def fault_seed():
    """Seed for the fault-injection suite.  CI sweeps REPRO_FAULT_SEED over a
    matrix so deterministic fault schedules get exercised from several
    starting states; locally it defaults to 0."""
    return int(os.environ.get("REPRO_FAULT_SEED", "0"))


@pytest.fixture(scope="session")
def conformance_seed():
    """Seed for the oracle-based conformance suite's randomized corpora.
    CI sweeps REPRO_CONFORMANCE_SEED over a matrix; locally defaults to 0."""
    return int(os.environ.get("REPRO_CONFORMANCE_SEED", "0"))
