"""RaBitQ quantization: packing, estimator quality, error bounds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import rabitq


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 40),
       d=st.integers(2, 200))
def test_pack_unpack_roundtrip(seed, n, d):
    rng = np.random.default_rng(seed)
    bits = rng.random((n, d)) > 0.5
    packed = rabitq.pack_bits(jnp.asarray(bits))
    signs = np.asarray(rabitq.unpack_bits(packed, d))
    np.testing.assert_array_equal(signs > 0, bits)


def test_rotation_is_orthogonal():
    for d in (8, 64, 100):
        P = np.asarray(rabitq.random_rotation(d, jax.random.PRNGKey(0)))
        np.testing.assert_allclose(P @ P.T, np.eye(d), atol=1e-4)


def test_estimator_relative_error_small(small_corpus):
    base = small_corpus["base"]
    q = small_corpus["queries"][0]
    codes = rabitq.fit(jnp.asarray(base), jax.random.PRNGKey(0))
    ctx = rabitq.prepare_query(codes, jnp.asarray(q))
    ids = jnp.arange(400, dtype=jnp.int32)
    est = np.asarray(rabitq.estimate_sqdist(codes, ctx, ids))
    true = np.sum((base[:400] - q) ** 2, axis=1)
    rel = np.abs(est - true) / np.maximum(true, 1e-9)
    assert rel.mean() < 0.15          # d=24: O(1/√d) noise
    assert np.median(rel) < 0.12


def test_estimator_approaches_truth_with_dim():
    """Concentration: relative error shrinks ~1/√d."""
    rng = np.random.default_rng(0)
    errs = []
    for d in (16, 128, 512):
        base = rng.normal(size=(300, d)).astype(np.float32)
        q = rng.normal(size=(d,)).astype(np.float32)
        codes = rabitq.fit(jnp.asarray(base), jax.random.PRNGKey(1))
        ctx = rabitq.prepare_query(codes, jnp.asarray(q))
        est = np.asarray(rabitq.estimate_sqdist(
            codes, ctx, jnp.arange(300, dtype=jnp.int32)))
        true = np.sum((base - q) ** 2, axis=1)
        errs.append(float(np.mean(np.abs(est - true) / true)))
    assert errs[2] < errs[1] < errs[0]
    assert errs[2] < 0.04


def test_estimator_unbiased_over_rotations():
    """⟨o,q⟩ estimate is (approximately) unbiased: averaging estimates over
    independent rotations converges to the true value."""
    rng = np.random.default_rng(0)
    d = 48
    base = rng.normal(size=(50, d)).astype(np.float32)
    q = rng.normal(size=(d,)).astype(np.float32)
    true = np.sum((base - q) ** 2, axis=1)
    ests = []
    for s in range(24):
        codes = rabitq.fit(jnp.asarray(base), jax.random.PRNGKey(s))
        ctx = rabitq.prepare_query(codes, jnp.asarray(q))
        ests.append(np.asarray(rabitq.estimate_sqdist(
            codes, ctx, jnp.arange(50, dtype=jnp.int32))))
    mean_est = np.mean(ests, axis=0)
    rel_bias = np.abs(mean_est - true) / true
    single_rel = np.mean(np.abs(ests[0] - true) / true)
    assert rel_bias.mean() < single_rel  # averaging reduces error ⇒ low bias
    assert rel_bias.mean() < 0.05


def test_error_bound_coverage(small_corpus):
    """The ε₀=2.2 high-probability bound should cover ≳95% of cases
    (the paper's ε₀≈1.9 targets d ≥ 128; at d=24 the tail is fatter)."""
    base = small_corpus["base"]
    codes = rabitq.fit(jnp.asarray(base), jax.random.PRNGKey(2))
    covered, total = 0, 0
    for qi in range(16):
        q = small_corpus["queries"][qi]
        ctx = rabitq.prepare_query(codes, jnp.asarray(q))
        ids = jnp.arange(300, dtype=jnp.int32)
        est = np.asarray(rabitq.estimate_sqdist(codes, ctx, ids))
        bound = np.asarray(rabitq.estimator_error_bound(codes, ids, eps0=2.2))
        true = np.sum((base[:300] - q) ** 2, axis=1)
        nv = np.linalg.norm(base[:300] - np.asarray(codes.center)[None], axis=1)
        nq = float(np.linalg.norm(q - np.asarray(codes.center)))
        # |est_d² − true_d²| = 2·‖v−c‖·‖q−c‖·|est_cos − cos|
        slack = 2 * nv * nq * bound
        covered += int(np.sum(np.abs(est - true) <= slack + 1e-6))
        total += 300
    assert covered / total > 0.95


def test_invalid_ids_inf():
    rng = np.random.default_rng(0)
    base = rng.normal(size=(20, 16)).astype(np.float32)
    codes = rabitq.fit(jnp.asarray(base), jax.random.PRNGKey(0))
    ctx = rabitq.prepare_query(codes, jnp.asarray(base[0]))
    est = rabitq.estimate_sqdist(codes, ctx,
                                 jnp.asarray([0, -1, 3], jnp.int32))
    assert bool(jnp.isinf(est[1])) and bool(jnp.isfinite(est[0]))
