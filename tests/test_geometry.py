"""Property tests for the occlusion geometry (Def. 9, Lemma 1)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional test dependency; every test here is a property test")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import geometry

DIMS = st.integers(min_value=2, max_value=16)


def _rand_vec(rng, d, scale=1.0):
    return rng.normal(size=(d,)).astype(np.float32) * scale


@settings(max_examples=200, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=DIMS,
       delta=st.floats(0.01, 0.9))
def test_lemma1_occluder_always_progresses(seed, d, delta):
    """Lemma 1: for w ∈ Occlusionδ(u,v) and any q with d(q,v) < δ·d(q,u),
    d(q,w) < d(q,u).  Sample w by rejection inside the region and q inside
    the navigable ball."""
    rng = np.random.default_rng(seed)
    u = _rand_vec(rng, d)
    v = u + _rand_vec(rng, d, 0.7) + 1e-2
    d_uv = float(np.linalg.norm(u - v))

    # rejection-sample an occluder w
    w = None
    for _ in range(300):
        cand = u + (v - u) * rng.uniform(0.1, 0.9) + _rand_vec(rng, d, 0.2 * d_uv)
        if bool(geometry.in_occlusion_region(
                jnp.asarray(cand), jnp.asarray(u), jnp.asarray(v), delta)):
            w = cand
            break
    if w is None:
        return  # region too small at this δ/geometry — vacuous draw

    # sample q in the open ball B(v/(1−δ²), δ‖v‖/(1−δ²)) (coords u at origin)
    c = u + (v - u) / (1 - delta**2)
    R = delta * d_uv / (1 - delta**2)
    dirn = _rand_vec(rng, d)
    dirn /= np.linalg.norm(dirn) + 1e-12
    q = c + dirn * R * rng.uniform(0.0, 0.999)
    # guard: the ball characterization must hold
    if not bool(np.linalg.norm(q - v) < delta * np.linalg.norm(q - u)):
        return

    assert np.linalg.norm(q - w) < np.linalg.norm(q - u) + 1e-6


@settings(max_examples=100, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=DIMS)
def test_delta_zero_limit_is_mrng_lune(seed, d):
    """As δ → 0 the region converges to the MRNG lune."""
    rng = np.random.default_rng(seed)
    u, v, x = _rand_vec(rng, d), _rand_vec(rng, d), _rand_vec(rng, d)
    d2_uv = float(np.sum((u - v) ** 2))
    d2_xu = float(np.sum((x - u) ** 2))
    d2_xv = float(np.sum((x - v) ** 2))
    tiny = bool(geometry.occludes_delta(d2_uv, d2_xu, d2_xv, 1e-7))
    lune = bool(geometry.occludes_mrng(d2_uv, d2_xu, d2_xv))
    # δ>0 region ⊆ lune, and at δ→0 they agree except a measure-zero boundary
    if tiny:
        assert lune
    if lune and not tiny:
        # must be a boundary case: d²(x,v) within ε of d²(u,v)
        assert d2_xv + 2e-7 * np.sqrt(d2_uv * d2_xu) >= d2_uv - 1e-4


@settings(max_examples=100, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=DIMS,
       d1=st.floats(0.05, 0.5), d2=st.floats(0.5, 0.95))
def test_occlusion_region_monotone_in_delta(seed, d, d1, d2):
    """Larger δ shrinks the region: Occlusion_{δ2} ⊆ Occlusion_{δ1}, δ1<δ2."""
    rng = np.random.default_rng(seed)
    u, v, x = _rand_vec(rng, d), _rand_vec(rng, d), _rand_vec(rng, d)
    args = (jnp.sum((u - v) ** 2), jnp.sum((x - u) ** 2), jnp.sum((x - v) ** 2))
    lo, hi = min(d1, d2), max(d1, d2)
    if bool(geometry.occludes_delta(*[jnp.asarray(a) for a in args], hi)):
        assert bool(geometry.occludes_delta(*[jnp.asarray(a) for a in args], lo))


def test_adaptive_deltas_schedule():
    d2 = jnp.asarray([0.25, 1.0, 4.0, 16.0])  # dists 0.5, 1, 2, 4
    deltas = geometry.adaptive_deltas(d2, t=2)  # d_(t) = 1.0
    np.testing.assert_allclose(np.asarray(deltas), [0.5, 0.0, -1.0, -3.0],
                               atol=1e-6)


def test_select_neighbors_first_always_kept():
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(20, 8)).astype(np.float32)
    u = vecs[0]
    cand = vecs[1:]
    d2 = np.sum((cand - u) ** 2, axis=1)
    order = np.argsort(d2)
    ids, count = geometry.select_neighbors(
        jnp.asarray(u), jnp.asarray(cand[order]), jnp.asarray(d2[order]),
        jnp.asarray(order.astype(np.int32) + 1),
        jnp.full((19,), 0.05), rule="delta_emg", max_keep=8)
    ids = np.asarray(ids)
    assert int(count) >= 1
    assert ids[0] == order[0] + 1  # nearest candidate always kept


def test_select_neighbors_rejects_self_and_invalid():
    rng = np.random.default_rng(1)
    vecs = rng.normal(size=(10, 4)).astype(np.float32)
    u = vecs[0]
    cand = np.concatenate([u[None], vecs[1:]])
    d2 = np.sum((cand - u) ** 2, axis=1)
    ids_in = np.arange(10, dtype=np.int32)
    ids_in[5] = -1
    ids, count = geometry.select_neighbors(
        jnp.asarray(u), jnp.asarray(cand), jnp.asarray(d2),
        jnp.asarray(ids_in), jnp.full((10,), 0.05), max_keep=8)
    ids = np.asarray(ids)[: int(count)]
    assert 0 not in ids.tolist()      # self (d²=0) excluded
    assert -1 not in ids.tolist()
