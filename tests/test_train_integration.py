"""Integration: end-to-end training convergence + fault-tolerant resume."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import lm_batch, make_markov_lm
from repro.models.transformer import LMConfig, init, loss_fn
from repro.optim import OptConfig
from repro.train import TrainState, make_train_step

CFG = LMConfig(name="it", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
               d_ff=128, vocab=128, dtype=jnp.float32)
OPT = OptConfig(lr=2e-3, total_steps=200, warmup_steps=10)


def _run(state, step_fn, lm, steps, start=0):
    losses = []
    for s in range(start, start + steps):
        toks, tgts = lm_batch(lm, 16, 32, s, seed=0)
        state, m = step_fn(state, {"tokens": jnp.asarray(toks),
                                   "targets": jnp.asarray(tgts)})
        losses.append(float(m["loss"]))
    return state, losses


@pytest.fixture(scope="module")
def setup():
    lm = make_markov_lm(128, branch=4, seed=0)
    params = init(CFG, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(
        lambda p, b: loss_fn(CFG, p, b["tokens"], b["targets"]), OPT))
    return lm, params, step_fn


def test_loss_decreases_toward_entropy_floor(setup):
    lm, params, step_fn = setup
    state = TrainState.create(params, OPT)
    state, losses = _run(state, step_fn, lm, 60)
    assert losses[-1] < losses[0] - 1.0          # big drop from ln(128)≈4.85
    assert losses[-1] < 3.0                      # well on the way to ln4≈1.39


def test_crash_resume_bitexact(setup, tmp_path):
    """Train 10 steps, checkpoint, 'crash', restore, continue — must match a
    run that never crashed (deterministic data keyed by step)."""
    lm, params, step_fn = setup

    # uninterrupted reference
    ref = TrainState.create(params, OPT)
    ref, ref_losses = _run(ref, step_fn, lm, 20)

    # interrupted run
    mgr = CheckpointManager(str(tmp_path), every=10, keep=2, async_save=False)
    st = TrainState.create(params, OPT)
    st, _ = _run(st, step_fn, lm, 10)
    mgr.maybe_save(10, st)
    del st                                        # 'crash'

    template = TrainState.create(params, OPT)
    step0, st2 = mgr.restore(template)
    assert step0 == 10
    assert int(st2.step) == 10
    st2, resumed_losses = _run(st2, step_fn, lm, 10, start=10)

    np.testing.assert_allclose(resumed_losses, ref_losses[10:], rtol=1e-5)
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(st2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=1e-6)


def test_accum_equivalence(setup):
    """accum=2 over half-size microbatches ≈ accum=1 over the full batch
    (f32 accumulation; identical data)."""
    lm, params, step_fn1 = setup
    step_fn2 = jax.jit(make_train_step(
        lambda p, b: loss_fn(CFG, p, b["tokens"], b["targets"]), OPT,
        accum_steps=2))
    toks, tgts = lm_batch(lm, 16, 32, 0, seed=0)
    s1 = TrainState.create(params, OPT)
    s2 = TrainState.create(params, OPT)
    s1, m1 = step_fn1(s1, {"tokens": jnp.asarray(toks),
                           "targets": jnp.asarray(tgts)})
    s2, m2 = step_fn2(s2, {"tokens": jnp.asarray(toks).reshape(2, 8, 32),
                           "targets": jnp.asarray(tgts).reshape(2, 8, 32)})
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
    a = jax.tree.leaves(s1.params)[0]
    b = jax.tree.leaves(s2.params)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_generate_after_training(setup):
    from repro.serve import generate

    lm, params, step_fn = setup
    state = TrainState.create(params, OPT)
    state, _ = _run(state, step_fn, lm, 40)
    prompt, _ = lm_batch(lm, 2, 4, 999, seed=0)
    toks = generate(CFG, state.params, jnp.asarray(prompt), max_new=8,
                    max_seq=16)
    assert toks.shape == (2, 12)
    # a trained model should follow chain successors more often than chance
    succ = lm.succ
    follows = 0
    arr = np.asarray(toks)
    for b in range(2):
        for t in range(4, 11):
            follows += int(arr[b, t + 1] in succ[arr[b, t]])
    assert follows / 14 > 0.3     # chance = 4/128 ≈ 0.03
