"""δ-EMQG construction + probing search (Algorithm 5) behaviour."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BuildParams,
    SearchParams,
    ags_search,
    build_emqg,
    error_bounded_probing_search,
    from_graph,
    memory_footprint,
    probing_search,
)

from conftest import recall_at_k


@pytest.fixture(scope="module")
def emqg(small_corpus):
    p = BuildParams(max_degree=24, beam_width=48, t=24, iters=2, block=512,
                    align_degree=True)
    return build_emqg(small_corpus["base"], p)


def test_degree_alignment_exact_m(emqg):
    """Sec 6.1: every out-degree == M (FastScan / lane alignment)."""
    deg = np.asarray(emqg.graph.degrees())
    assert (deg == 24).mean() > 0.98   # connectivity repair may nudge a few
    assert deg.min() >= 20


def test_probing_recall(emqg, small_corpus):
    res = error_bounded_probing_search(
        emqg, jnp.asarray(small_corpus["queries"]), k=10, alpha=2.0, l_max=128)
    assert recall_at_k(res.ids, small_corpus["gt_i"], 10) > 0.8


def test_probing_counters(emqg, small_corpus):
    """Probing must trade exact for approximate computations: far fewer
    exact evaluations than a pure-exact search of the same width."""
    from repro.core import error_bounded_search

    q = jnp.asarray(small_corpus["queries"])
    r_prob = error_bounded_probing_search(emqg, q, k=10, alpha=1.5, l_max=96)
    r_exact = error_bounded_search(emqg.graph, q, k=10, alpha=1.5, l_max=96)
    assert float(np.mean(np.asarray(r_prob.n_dist_comps))) < \
        float(np.mean(np.asarray(r_exact.n_dist_comps)))
    assert float(np.mean(np.asarray(r_prob.n_approx_comps))) > 0


def test_probing_results_have_exact_distances(emqg, small_corpus):
    res = error_bounded_probing_search(
        emqg, jnp.asarray(small_corpus["queries"][:8]), k=5, alpha=1.5,
        l_max=64)
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    base = small_corpus["base"]
    qs = small_corpus["queries"][:8]
    expect = np.linalg.norm(base[ids.ravel()].reshape(ids.shape + (-1,))
                            - qs[:, None, :], axis=-1)
    np.testing.assert_allclose(dists, expect, rtol=1e-4, atol=1e-4)


def test_ags_ablation_runs(emqg, small_corpus):
    p = SearchParams(k=10, l0=48, l_max=48, adaptive=False, max_hops=512)
    res = ags_search(emqg, jnp.asarray(small_corpus["queries"]), p)
    assert recall_at_k(res.ids, small_corpus["gt_i"], 10) > 0.5


def test_probing_with_pallas_kernel(emqg, small_corpus):
    """use_kernel=True routes S₊ through the Pallas bitdot kernel; results
    must agree with the jnp path."""
    p = SearchParams(k=5, l0=5, l_max=48, alpha=1.3, adaptive=True,
                     max_hops=256)
    q = jnp.asarray(small_corpus["queries"][:8])
    r1 = probing_search(emqg, q, p, use_kernel=False)
    r2 = probing_search(emqg, q, p, use_kernel=True)
    assert (np.asarray(r1.ids) == np.asarray(r2.ids)).all()


def test_from_graph_and_footprint(small_corpus):
    from repro.core import build_approx

    g = build_approx(small_corpus["base"],
                     BuildParams(max_degree=16, beam_width=32, t=8, iters=1))
    idx = from_graph(g)
    fp = memory_footprint(idx)
    n, d = small_corpus["base"].shape
    assert fp["codes"] == n * ((d + 31) // 32) * 4
    assert fp["vectors"] == n * d * 4
    # 1-bit codes ≈ 32× smaller than f32 vectors (d=24 pads to one whole
    # uint32 word → exactly 24× here)
    assert fp["codes"] * 8 < fp["vectors"]
    assert fp["codes"] * 24 <= fp["vectors"]
