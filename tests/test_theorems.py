"""End-to-end validation of the paper's theorems on exact δ-EMG builds."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SearchParams,
    build_exact,
    error_bounded_search,
    greedy_search,
    local_optimum_mask,
    search,
    theorem4_delta_prime,
)
from repro.core.distances import brute_force_knn

from conftest import gmm


@pytest.fixture(scope="module")
def exact_graph():
    base = gmm(600, 16, 12, seed=3)
    return build_exact(base, delta=0.1), base


def test_theorem1_in_dataset_query_reaches_itself(exact_graph):
    """Monotonic top-1 search with q ∈ V terminates at q (Thm. 1)."""
    g, base = exact_graph
    qs = jnp.asarray(base[::37])
    res = greedy_search(g, qs, k=1, l=1, max_hops=2048)
    ids = np.asarray(res.ids)[:, 0]
    assert (ids == np.arange(0, 600, 37)).all()


def test_theorem2_arbitrary_query_error_bound(exact_graph):
    """Greedy top-1 from ANY start is a (1/δ)-approximation (Thm. 2)."""
    g, base = exact_graph
    rng = np.random.default_rng(7)
    queries = gmm(48, 16, 12, seed=11) + 0.1 * rng.normal(size=(48, 16)).astype(np.float32)
    gt_d, _ = brute_force_knn(queries, base, 1)
    starts = rng.integers(0, 600, 48).astype(np.int32)
    res = greedy_search(g, jnp.asarray(queries), k=1, l=1, max_hops=2048)
    found = np.asarray(res.dists)[:, 0]
    # d(q, r) ≤ (1/δ)·d(q, v₁)
    assert (found <= gt_d[:, 0] / 0.1 + 1e-4).all()
    # also from random starts, not just the medoid
    p = SearchParams(k=1, l0=1, l_max=1, adaptive=False, max_hops=2048)
    res2 = search(g, jnp.asarray(queries), p, start=jnp.asarray(starts))
    found2 = np.asarray(res2.dists)[:, 0]
    assert (found2 <= gt_d[:, 0] / 0.1 + 1e-4).all()


def test_theorem4_rank_aware_topk_bound(exact_graph):
    """When a local optimum exists in C \\ R_k, every returned r_(i) obeys
    d(q, r_(i)) ≤ (1/δ')·d(q, v_(i)) with δ' = δ·d(q,u)/d(q,r_(k))."""
    g, base = exact_graph
    queries = gmm(48, 16, 12, seed=13)
    k = 5
    gt_d, _ = brute_force_knn(queries, base, k)
    p = SearchParams(k=k, l0=k, l_max=64, alpha=2.0, adaptive=True,
                     max_hops=2048)
    res, cand_ids, cand_dists = search(g, jnp.asarray(queries), p,
                                       with_candidates=True)
    found, dprime = theorem4_delta_prime(
        g, jnp.asarray(queries), cand_ids, cand_dists, k=k, delta=0.1)
    found = np.asarray(found)
    dprime = np.asarray(dprime)
    dists = np.asarray(res.dists)
    assert found.mean() > 0.5  # local optima common (paper Exp-6)
    for i in np.where(found)[0]:
        bound = gt_d[i] / max(dprime[i], 1e-9)
        assert (dists[i] <= bound + 1e-4).all(), (dists[i], bound)


def test_delta_prime_stronger_than_delta(exact_graph):
    """Exp-7: achieved δ′ ≥ build δ (farther local optima tighten it)."""
    g, base = exact_graph
    queries = gmm(48, 16, 12, seed=17)
    p = SearchParams(k=5, l0=5, l_max=64, alpha=2.5, adaptive=True,
                     max_hops=2048)
    _, cand_ids, cand_dists = search(g, jnp.asarray(queries), p,
                                     with_candidates=True)
    found, dprime = theorem4_delta_prime(
        g, jnp.asarray(queries), cand_ids, cand_dists, k=5, delta=0.1)
    d = np.asarray(dprime)[np.asarray(found)]
    assert d.size > 0
    assert np.mean(d >= 0.1) > 0.9


def test_local_optimum_mask_brute_check(exact_graph):
    g, base = exact_graph
    queries = jnp.asarray(gmm(8, 16, 12, seed=19))
    cand_ids = jnp.asarray(
        np.random.default_rng(0).integers(0, 600, (8, 12)).astype(np.int32))
    mask = np.asarray(local_optimum_mask(g, queries, cand_ids))
    nbrs = np.asarray(g.neighbors)
    for b in range(8):
        q = np.asarray(queries[b])
        for j in range(12):
            c = int(cand_ids[b, j])
            ns = nbrs[c]
            ns = ns[ns >= 0]
            dc = np.linalg.norm(base[c] - q)
            dn = np.linalg.norm(base[ns] - q, axis=1).min()
            assert bool(mask[b, j]) == bool(dn >= dc)
