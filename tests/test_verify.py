"""Graph-invariant auditor: clean indexes pass, each corruption class is
caught as the right violation, and the serve CLI surfaces it via --audit."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BuildParams, build_approx
from repro.core.updates import as_live, delete, insert
from repro.core.verify import audit, audit_live

BP = BuildParams(max_degree=10, beam_width=20, t=10, iters=2, block=128)


@pytest.fixture(scope="module")
def graph():
    rng = np.random.default_rng(5)
    return build_approx(rng.standard_normal((200, 10)).astype(np.float32), BP)


def _with_neighbors(graph, nbr):
    return dataclasses.replace(graph, neighbors=jnp.asarray(nbr))


def test_clean_graph_passes(graph):
    rep = audit(graph)
    assert rep.ok, rep.summary()
    assert rep.n_live == rep.n == 200
    assert rep.metrics["n_unreachable_live"] == 0
    assert rep.metrics["monotone_failures"] <= 3   # ≤ tol on an approx build


def test_mutated_live_index_passes(graph):
    live = as_live(graph, BP)
    live = insert(live, np.random.default_rng(6)
                  .standard_normal((15, 10)).astype(np.float32))
    live = delete(live, [2, 8, 31])
    rep = audit_live(live)
    assert rep.ok, rep.summary()
    assert rep.n_live == 215 - 3


def test_out_of_range_ids_flagged(graph):
    nbr = np.asarray(graph.neighbors).copy()
    nbr[3, 0] = graph.n + 50
    rep = audit(_with_neighbors(graph, nbr))
    assert not rep.ok
    assert any("out of range" in v for v in rep.violations)


def test_self_loops_and_duplicates_flagged(graph):
    nbr = np.asarray(graph.neighbors).copy()
    nbr[4, 0] = 4                                  # self loop
    nbr[5, 1] = nbr[5, 0]                          # duplicate edge
    rep = audit(_with_neighbors(graph, nbr))
    assert any("self-loop" in v for v in rep.violations)
    assert any("duplicate" in v for v in rep.violations)


def test_unreachable_live_node_flagged(graph):
    nbr = np.asarray(graph.neighbors).copy()
    victim = (int(np.asarray(graph.medoid)) + 1) % graph.n
    nbr[nbr == victim] = -1                        # sever every in-edge
    rep = audit(_with_neighbors(graph, nbr))
    assert not rep.ok
    assert any("unreachable" in v for v in rep.violations)


def test_isolated_live_node_flagged(graph):
    nbr = np.asarray(graph.neighbors).copy()
    victim = (int(np.asarray(graph.medoid)) + 1) % graph.n
    nbr[victim, :] = -1
    nbr[nbr == victim] = -1
    rep = audit(_with_neighbors(graph, nbr))
    assert any("isolated" in v for v in rep.violations)


def test_tombstoned_medoid_flagged(graph):
    tomb = np.zeros(graph.n, bool)
    tomb[int(np.asarray(graph.medoid))] = True
    rep = audit(graph, tombstones=tomb)
    assert any("medoid" in v and "tombstoned" in v for v in rep.violations)


def test_tombstone_bitmap_shape_flagged(graph):
    rep = audit(graph, tombstones=np.zeros(graph.n - 1, bool))
    assert any("bitmap shape" in v for v in rep.violations)


def test_broken_routing_flagged_by_monotone_probe(graph):
    """Rewiring every node to the same few targets keeps the graph fully
    reachable (those hubs point back) yet destroys monotone descent — only
    the sampled probe catches this class of defect."""
    n = graph.n
    nbr = np.full_like(np.asarray(graph.neighbors), -1)
    hubs = [int(np.asarray(graph.medoid)), (int(np.asarray(graph.medoid))
                                            + 1) % n]
    for i in range(n):
        nbr[i, 0] = hubs[0] if i != hubs[0] else hubs[1]
        nbr[i, 1] = hubs[1] if i != hubs[1] else (hubs[1] + 1) % n
    nbr[hubs[0], : graph.max_degree] = \
        [i for i in range(n) if i != hubs[0]][: graph.max_degree]
    rep = audit(_with_neighbors(graph, nbr))
    assert not rep.ok
    assert any("monotone" in v or "unreachable" in v for v in rep.violations)


def test_summary_mentions_violations(graph):
    nbr = np.asarray(graph.neighbors).copy()
    nbr[0, 0] = 0
    rep = audit(_with_neighbors(graph, nbr))
    text = rep.summary()
    assert "VIOLATION" in text and "self-loop" in text


def test_serve_cli_audit_flag():
    import subprocess
    import sys

    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--n", "400", "--dim",
         "16", "--queries", "32", "--audit"],
        capture_output=True, text=True, timeout=560, cwd="/root/repo",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "[audit]" in proc.stdout and "OK" in proc.stdout
