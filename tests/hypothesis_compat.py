"""Optional-dependency guard for hypothesis.

``hypothesis`` is declared as a test extra in pyproject.toml, but the suite
must degrade gracefully when it is absent (the paper-repro container bakes a
fixed environment): importing this module instead of ``hypothesis`` directly
turns every ``@given`` property test into a pytest skip rather than killing
collection of the whole module — non-property tests in the same file keep
running.

Usage (replaces ``from hypothesis import given, settings, strategies as st``):

    from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (optional test dependency)"
            )(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Accepts any strategy construction; never executed (tests skip)."""

        def __getattr__(self, name):
            def strategy(*_args, **_kwargs):
                return None

            return strategy

    st = _StrategyStub()
