"""Kernel microbenchmarks: Pallas (interpret on CPU) vs jnp reference —
correctness-at-scale plus a CPU wall-clock proxy.  The real perf claim for
kernels is structural (BlockSpec tiling, §Roofline); these numbers guard
against regressions in the wrappers."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitdot.ops import bitdot, fused_estimate
from repro.kernels.l2dist.ops import batched_l2

from .common import emit, save_json


def _time(fn, *args, repeats=3):
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {}

    B, M, d = 64, 64, 128
    rows = jnp.asarray(rng.normal(size=(B, M, d)).astype(np.float32))
    qs = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    t_ref, o_ref = _time(lambda r, q: batched_l2(r, q, use_ref=True), rows, qs)
    t_pal, o_pal = _time(batched_l2, rows, qs)
    err = float(jnp.max(jnp.abs(o_ref - o_pal)))
    out["batched_l2"] = {"ref_s": t_ref, "pallas_interpret_s": t_pal, "maxerr": err}
    emit("kernel_batched_l2_ref", t_ref * 1e6, f"B{B}xM{M}xd{d}")
    emit("kernel_batched_l2_pallas", t_pal * 1e6, f"maxerr={err:.1e}")

    m, dim = 4096, 128
    W = dim // 32
    codes = jnp.asarray(rng.integers(0, 2**32, (m, W), dtype=np.uint64).astype(np.uint32))
    q = jnp.asarray(rng.normal(size=(dim,)).astype(np.float32))
    t_ref, s_ref = _time(lambda c, qq: bitdot(c, qq, use_ref=True), codes, q)
    t_pal, s_pal = _time(bitdot, codes, q)
    err = float(jnp.max(jnp.abs(s_ref - s_pal)))
    out["bitdot"] = {"ref_s": t_ref, "pallas_interpret_s": t_pal, "maxerr": err}
    emit("kernel_bitdot_ref", t_ref * 1e6, f"m{m}xd{dim}")
    emit("kernel_bitdot_pallas", t_pal * 1e6, f"maxerr={err:.1e}")

    norms = jnp.asarray((0.5 + np.abs(rng.normal(size=m))).astype(np.float32))
    ipxo = jnp.asarray((0.5 + 0.4 * rng.random(m)).astype(np.float32))
    t_f, o_f = _time(lambda c, qq: fused_estimate(c, norms, ipxo, qq,
                                                  jnp.float32(1.5), dim),
                     codes, q)
    out["fused_estimate"] = {"pallas_interpret_s": t_f}
    emit("kernel_fused_estimate", t_f * 1e6, f"m{m}xd{dim}")
    save_json("kernels_bench", out)
    return out


if __name__ == "__main__":
    run()
