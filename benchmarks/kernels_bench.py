"""Kernel + engine microbenchmarks: Pallas (interpret on CPU) vs jnp
reference, and the beam engine's beam-width sweep.

Two kinds of rows:

* Kernel correctness-at-scale with a CPU wall-clock proxy — the real perf
  claim for kernels is structural (BlockSpec tiling, multi-row DMA blocks,
  §Roofline); these numbers guard against regressions in the wrappers.
* Engine distance-evaluation throughput (evals/s) at serving batch sizes,
  swept over ``beam_width`` with W=1 as the baseline — the batch engine
  evaluates ``B×W×M`` distances in a single fused gather+L2 call per
  lock-step hop, and the packed visited bitset keeps dedup O(1) per
  neighbor.

Results land in ``benchmarks/results/kernels_bench.json`` and in the repo
root ``BENCH_kernels.json`` (the perf-trajectory file CI uploads).
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SearchParams, search
from repro.kernels.bitdot.ops import bitdot, fused_estimate
from repro.kernels.l2dist.ops import batched_l2, gather_l2, gather_l2_tiled

from .common import corpus, emit, index_emg, save_json


def _time(fn, *args, repeats=3):
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def _bench_gather(out: dict) -> None:
    """Single-row vs tiled gather_l2 vs the jnp reference."""
    rng = np.random.default_rng(1)
    n, d = 8192, 128
    base = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    for B, M in ((8, 64), (64, 96)):
        ids = jnp.asarray(rng.integers(0, n, (B, M)).astype(np.int32))
        qs = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
        t_ref, o_ref = _time(
            lambda b, i, q: gather_l2(b, i, q, use_ref=True), base, ids, qs)
        t_row, o_row = _time(gather_l2, base, ids, qs)
        t_til, o_til = _time(gather_l2_tiled, base, ids, qs)
        err_row = float(jnp.max(jnp.abs(o_ref - o_row)))
        err_til = float(jnp.max(jnp.abs(o_ref - o_til)))
        key = f"gather_l2_B{B}xM{M}"
        out[key] = {
            "ref_s": t_ref,
            "pallas_single_row_interpret_s": t_row,
            "pallas_tiled_interpret_s": t_til,
            "maxerr_single_row": err_row,
            "maxerr_tiled": err_til,
        }
        emit(f"kernel_{key}_ref", t_ref * 1e6, f"n{n}xd{d}")
        emit(f"kernel_{key}_single_row", t_row * 1e6, f"maxerr={err_row:.1e}")
        emit(f"kernel_{key}_tiled", t_til * 1e6, f"maxerr={err_til:.1e}")


def _bench_engines(out: dict) -> None:
    """Beam-width sweep on the batch engine: distance evals per second at
    serving batch sizes (B ≥ 32 is the acceptance bar), W=1 greedy
    best-first as the baseline."""
    base, queries, _gt_d, _gt_i = corpus()
    g = index_emg()
    rows = []
    for B in (32, 64):
        q = jnp.asarray(queries[:B])
        t_base = None
        for W in (1, 4, 8):

            def beam_fn(qq, w=W):
                p = SearchParams(k=10, l0=10, l_max=96, alpha=1.5,
                                 adaptive=True, max_hops=2048, beam_width=w)
                return search(g, qq, p)  # backend="auto": kernel on TPU

            t_beam, r_beam = _time(beam_fn, q)
            if t_base is None:
                t_base = t_beam
            evals = float(np.sum(np.asarray(r_beam.n_dist_comps)))
            tput = evals / t_beam
            rows.append({"engine": "beam_batch", "B": B, "beam_width": W,
                         "time_s": t_beam, "dist_evals": evals,
                         "evals_per_s": tput,
                         "speedup_vs_w1": t_base / t_beam})
            emit(f"engine_beam_B{B}_W{W}", t_beam * 1e6,
                 f"evals/s={tput:.3e} speedup_vs_w1={t_base / t_beam:.2f}x")
    out["engine_dist_throughput"] = rows
    out["engine_summary"] = {
        "best_beam_evals_per_s": max(r["evals_per_s"] for r in rows),
        "w1_evals_per_s": max(
            r["evals_per_s"] for r in rows if r["beam_width"] == 1),
    }


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {"backend": jax.default_backend()}

    B, M, d = 64, 64, 128
    rows = jnp.asarray(rng.normal(size=(B, M, d)).astype(np.float32))
    qs = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    t_ref, o_ref = _time(lambda r, q: batched_l2(r, q, use_ref=True), rows, qs)
    t_pal, o_pal = _time(batched_l2, rows, qs)
    err = float(jnp.max(jnp.abs(o_ref - o_pal)))
    out["batched_l2"] = {"ref_s": t_ref, "pallas_interpret_s": t_pal, "maxerr": err}
    emit("kernel_batched_l2_ref", t_ref * 1e6, f"B{B}xM{M}xd{d}")
    emit("kernel_batched_l2_pallas", t_pal * 1e6, f"maxerr={err:.1e}")

    _bench_gather(out)

    m, dim = 4096, 128
    W = dim // 32
    codes = jnp.asarray(rng.integers(0, 2**32, (m, W), dtype=np.uint64).astype(np.uint32))
    q = jnp.asarray(rng.normal(size=(dim,)).astype(np.float32))
    t_ref, s_ref = _time(lambda c, qq: bitdot(c, qq, use_ref=True), codes, q)
    t_pal, s_pal = _time(bitdot, codes, q)
    err = float(jnp.max(jnp.abs(s_ref - s_pal)))
    out["bitdot"] = {"ref_s": t_ref, "pallas_interpret_s": t_pal, "maxerr": err}
    emit("kernel_bitdot_ref", t_ref * 1e6, f"m{m}xd{dim}")
    emit("kernel_bitdot_pallas", t_pal * 1e6, f"maxerr={err:.1e}")

    norms = jnp.asarray((0.5 + np.abs(rng.normal(size=m))).astype(np.float32))
    ipxo = jnp.asarray((0.5 + 0.4 * rng.random(m)).astype(np.float32))
    t_f, o_f = _time(lambda c, qq: fused_estimate(c, norms, ipxo, qq,
                                                  jnp.float32(1.5), dim),
                     codes, q)
    out["fused_estimate"] = {"pallas_interpret_s": t_f}
    emit("kernel_fused_estimate", t_f * 1e6, f"m{m}xd{dim}")

    _bench_engines(out)

    save_json("kernels_bench", out)
    root_path = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_kernels.json")
    with open(os.path.abspath(root_path), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    run()
