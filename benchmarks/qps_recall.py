"""Exp-1 (Fig. 3): QPS vs recall across methods, k ∈ {1, 10, 100}.

δ-EMG / δ-EMQG sweep the accuracy parameter α; the baselines sweep their
search width l — exactly the paper's protocol.  The δ-EMG/δ-EMQG rows also
report p50/p95/p99 batch latency from the shared ``repro.obs.Histogram``
(identical bucket math to the serve layer's latency families), alongside
the best-of-repeats mean QPS."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    SearchParams,
    error_bounded_probing_search,
    error_bounded_search,
    greedy_search,
)
from repro.obs import Histogram

from . import common
from .common import corpus, emit, index_baseline, index_emg, index_emqg, recall, timed_qps

ALPHAS = (1.0, 1.1, 1.4, 2.0, 3.0)
WIDTHS = (16, 40, 96)
BEAM_WIDTHS = (1, 4)   # per-hop frontier of the lock-step batch engine
LAT_REPEATS = 5        # repeats feeding the latency histogram rows


def _lat_fields(hist: Histogram) -> dict:
    """p50/p95/p99 batch latency (seconds) from the shared histogram."""
    pct = hist.percentiles()
    return {f"lat_{k}_s": v for k, v in pct.items()}


def run(k_values=(1, 10)) -> dict:  # k=100 representable; 1-core trace cost prohibitive
    base, queries, gt_d, gt_i = corpus()
    q = jnp.asarray(queries)
    results = {}

    for k in k_values:
        rows = []
        g = index_emg()
        for alpha in ALPHAS:
            for bw in BEAM_WIDTHS:
                hist = Histogram()
                qps, res = timed_qps(
                    lambda qq, a=alpha, w=bw: error_bounded_search(
                        g, qq, k=k, alpha=a, l_max=max(192, 2 * k),
                        beam_width=w), q, repeats=LAT_REPEATS, hist=hist)
                method = "delta-emg" if bw == 1 else f"delta-emg-bw{bw}"
                rows.append({"method": method, "param": alpha,
                             "recall": recall(res.ids, gt_i, k), "qps": qps,
                             "ndist": float(np.mean(np.asarray(res.n_dist_comps))),
                             **_lat_fields(hist)})
        idx = index_emqg()
        for alpha in ALPHAS:
            hist = Histogram()
            qps, res = timed_qps(
                lambda qq, a=alpha: error_bounded_probing_search(
                    idx, qq, k=k, alpha=a, l_max=max(192, 2 * k)), q,
                repeats=LAT_REPEATS, hist=hist)
            rows.append({"method": "delta-emqg", "param": alpha,
                         "recall": recall(res.ids, gt_i, k), "qps": qps,
                         "ndist": float(np.mean(np.asarray(res.n_dist_comps))),
                         **_lat_fields(hist)})
        for kind in ("nsg", "tau_mg", "vamana", "nsw", "knn"):
            gb = index_baseline(kind)
            for l in WIDTHS:
                if l < k:
                    continue
                qps, res = timed_qps(
                    lambda qq, ll=l, gg=gb: greedy_search(gg, qq, k=k, l=ll), q)
                rows.append({"method": kind, "param": l,
                             "recall": recall(res.ids, gt_i, k), "qps": qps,
                             "ndist": float(np.mean(np.asarray(res.n_dist_comps)))})
        results[f"k={k}"] = rows

        # headline: best QPS at ≥0.9 recall per method
        for method in ("delta-emg", "delta-emg-bw4", "delta-emqg", "nsg",
                       "tau_mg", "vamana", "nsw", "knn"):
            ok = [r for r in rows if r["method"] == method and r["recall"] >= 0.9]
            if ok:
                best = max(ok, key=lambda r: r["qps"])
                emit(f"exp1_qps_at_r90_k{k}_{method}",
                     1e6 / best["qps"], f"recall={best['recall']:.3f}")
            else:
                best = max((r for r in rows if r["method"] == method),
                           key=lambda r: r["recall"])
                emit(f"exp1_qps_at_r90_k{k}_{method}", 0.0,
                     f"max_recall={best['recall']:.3f} (<0.9)")
    common.save_json("exp1_qps_recall", results)
    return results


if __name__ == "__main__":
    run()
