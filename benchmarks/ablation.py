"""Exp-9 (Fig. 10): ablation — swap out either the graph construction or the
search algorithm:

  δ-EMG-NSG  : error-bounded search (Alg. 3) on an NSG graph
  δ-EMG-GS   : plain greedy search (Alg. 1) on the δ-EMG graph
  δ-EMQG-NSG : probing search (Alg. 5) on a quantized NSG graph
  δ-EMQG-AGS : approximate greedy search on the δ-EMQG
vs the full systems."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    SearchParams,
    ags_search,
    error_bounded_probing_search,
    error_bounded_search,
    from_graph,
    greedy_search,
)

from . import common
from .common import corpus, emit, index_baseline, index_emg, index_emqg, recall, timed_qps

K = 10
ALPHAS = (1.0, 1.3, 2.0, 3.0)
WIDTHS = (16, 48, 96)


def _curve_alpha(search_fn, q, gt_i):
    rows = []
    for a in ALPHAS:
        qps, res = timed_qps(lambda qq, aa=a: search_fn(qq, aa), q)
        rows.append({"param": a, "recall": recall(res.ids, gt_i, K), "qps": qps})
    return rows


def _curve_width(search_fn, q, gt_i):
    rows = []
    for l in WIDTHS:
        qps, res = timed_qps(lambda qq, ll=l: search_fn(qq, ll), q)
        rows.append({"param": l, "recall": recall(res.ids, gt_i, K), "qps": qps})
    return rows


def run() -> dict:
    base, queries, gt_d, gt_i = corpus()
    q = jnp.asarray(queries)
    g_emg = index_emg()
    idx_emqg = index_emqg()
    g_nsg = index_baseline("nsg")
    idx_nsg_q = from_graph(g_nsg)

    out = {
        "delta-emg (full)": _curve_alpha(
            lambda qq, a: error_bounded_search(g_emg, qq, k=K, alpha=a,
                                               l_max=192), q, gt_i),
        "delta-emg-nsg": _curve_alpha(
            lambda qq, a: error_bounded_search(g_nsg, qq, k=K, alpha=a,
                                               l_max=192), q, gt_i),
        "delta-emg-gs": _curve_width(
            lambda qq, l: greedy_search(g_emg, qq, k=K, l=l), q, gt_i),
        "delta-emqg (full)": _curve_alpha(
            lambda qq, a: error_bounded_probing_search(
                idx_emqg, qq, k=K, alpha=a, l_max=192), q, gt_i),
        "delta-emqg-nsg": _curve_alpha(
            lambda qq, a: error_bounded_probing_search(
                idx_nsg_q, qq, k=K, alpha=a, l_max=192), q, gt_i),
        "delta-emqg-ags": _curve_width(
            lambda qq, l: ags_search(
                idx_emqg, qq, SearchParams(k=K, l0=l, l_max=l, adaptive=False,
                                           max_hops=1024)), q, gt_i),
    }
    for name, rows in out.items():
        ok = [r for r in rows if r["recall"] >= 0.9]
        if ok:
            best = max(ok, key=lambda r: r["qps"])
            emit(f"exp9_{name.replace(' ', '_')}", 1e6 / best["qps"],
                 f"recall={best['recall']:.3f}")
        else:
            best = max(rows, key=lambda r: r["recall"])
            emit(f"exp9_{name.replace(' ', '_')}", 0.0,
                 f"max_recall={best['recall']:.3f} (<0.9)")
    common.save_json("exp9_ablation", out)
    return out


if __name__ == "__main__":
    run()
