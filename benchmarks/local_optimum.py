"""Exp-6 (Fig. 8a) + Exp-7 (Fig. 8b): the error-bounded framework's empirical
validation — probability of finding a local-optimum node in the final
candidate set, and the achieved bound δ′, both as functions of α."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import (
    BuildParams,
    SearchParams,
    build_approx,
    search,
    theorem4_delta_prime,
)

from . import common
from .common import BEAM, M_DEG, corpus, emit

K = 10
DELTA_BUILD = 0.04
ALPHAS = (1.0, 1.2, 1.5, 2.0, 2.5, 3.0)


def run() -> dict:
    base, queries, gt_d, gt_i = corpus()
    q = jnp.asarray(queries)
    # fixed-δ graph, as the paper does for this experiment
    g = build_approx(base, BuildParams(max_degree=M_DEG, beam_width=BEAM,
                                       t=16, iters=2, delta=DELTA_BUILD,
                                       block=512))
    rows = []
    for alpha in ALPHAS:
        p = SearchParams(k=K, l0=K, l_max=256, alpha=alpha, adaptive=True,
                         max_hops=2048)
        res, cand_ids, cand_dists = search(g, q, p, with_candidates=True)
        found, dprime = theorem4_delta_prime(g, q, cand_ids, cand_dists,
                                             k=K, delta=DELTA_BUILD)
        found = np.asarray(found)
        dp = np.asarray(dprime)[found]
        rows.append({
            "alpha": alpha,
            "p_local_opt": float(found.mean()),
            "mean_delta_prime": float(dp.mean()) if dp.size else 0.0,
        })
        emit(f"exp6_p_localopt_a{alpha}", 0.0,
             f"p={rows[-1]['p_local_opt']:.3f}")
        emit(f"exp7_delta_prime_a{alpha}", 0.0,
             f"dp={rows[-1]['mean_delta_prime']:.4f};build_delta={DELTA_BUILD}")
    common.save_json("exp6_exp7_local_optimum", rows)
    return rows


if __name__ == "__main__":
    run()
