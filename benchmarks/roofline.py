"""§Roofline: three-term roofline per (arch × shape × mesh) from the
dry-run's compiled artifacts (benchmarks/results/dryrun.json).

    compute term    = HLO_FLOPs / (chips × 197 TFLOP/s)
    memory term     = HLO_bytes / (chips × 819 GB/s)
    collective term = collective_bytes / (chips × 50 GB/s/link)

cost_analysis() reports the per-device partitioned module, so the per-device
figures are divided by per-chip peak directly (equivalent to the global
formula).  Collective bytes use the ring-model wire accounting described in
launch/dryrun.parse_collectives.

Also reports MODEL_FLOPS (6·N·D / 6·N_active·D) and the useful-compute ratio
MODEL_FLOPS / (HLO_FLOPs × chips), which exposes remat/redundancy waste.
"""

from __future__ import annotations

import json
import os

from .common import RESULTS_DIR, emit

DRYRUN_JSON = os.path.join(RESULTS_DIR, "dryrun.json")


def load(path: str = DRYRUN_JSON):
    with open(path) as f:
        return json.load(f)


def table(records, mesh_filter: str = "single_pod_16x16"):
    rows = []
    for r in records:
        if r["mesh"] != mesh_filter:
            continue
        if r["status"] == "skip":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "skip", "reason": r["skip_reason"][:60]})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "error", "reason": r["error"][:60]})
            continue
        rf = r["roofline"]
        total = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "compute_ms": rf["compute_s"] * 1e3,
            "memory_ms": rf["memory_s"] * 1e3,
            "collective_ms": rf["collective_s"] * 1e3,
            "bottleneck": rf["bottleneck"],
            "roofline_frac": rf["compute_s"] / total if total else 0.0,
            "useful_flops_ratio": rf["useful_flops_ratio"],
            "mem_gib": r["memory"]["peak_per_device_bytes"] / 2**30,
        })
    return rows


def run() -> list:
    if not os.path.exists(DRYRUN_JSON):
        emit("roofline", 0.0, "dryrun.json missing — run repro.launch.dryrun")
        return []
    rows = table(load())
    print(f"{'arch':<26} {'shape':<14} {'comp ms':>8} {'mem ms':>8} "
          f"{'coll ms':>8} {'bound':>10} {'frac':>5} {'useful':>6} {'GiB':>6}")
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:<26} {r['shape']:<14} {r['status'].upper()}: "
                  f"{r['reason']}")
            continue
        print(f"{r['arch']:<26} {r['shape']:<14} {r['compute_ms']:>8.2f} "
              f"{r['memory_ms']:>8.2f} {r['collective_ms']:>8.2f} "
              f"{r['bottleneck']:>10} {r['roofline_frac']:>5.2f} "
              f"{r['useful_flops_ratio']:>6.2f} {r['mem_gib']:>6.1f}")
        emit(f"roofline_{r['arch']}_{r['shape']}",
             max(r["compute_ms"], r["memory_ms"], r["collective_ms"]) * 1e3,
             f"bound={r['bottleneck']};frac={r['roofline_frac']:.2f}")
    return rows


if __name__ == "__main__":
    run()
