"""Exp-5 (Fig. 7): distance computations vs Relative Distance Error.

RDE = mean_i (d(q, r_(i)) − d(q, v_(i))) / d(q, v_(i)) — the paper's
error-bounded metric; δ-EMG should reach a given RDE with fewer distance
computations than the non-quantized baselines."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import error_bounded_search, greedy_search

from . import common
from .common import corpus, emit, index_baseline, index_emg

K = 10
ALPHAS = (1.0, 1.1, 1.4, 2.5, 4.0)
WIDTHS = (16, 40, 96, 160)


def _rde(dists, gt_d, k=K) -> float:
    d = np.asarray(dists)[:, :k]
    g = gt_d[:, :k]
    return float(np.mean((d - g) / np.maximum(g, 1e-9)))


def run() -> dict:
    base, queries, gt_d, gt_i = corpus()
    q = jnp.asarray(queries)
    out = {}

    rows = []
    g = index_emg()
    for alpha in ALPHAS:
        res = error_bounded_search(g, q, k=K, alpha=alpha, l_max=256)
        rows.append({"param": alpha,
                     "rde": _rde(res.dists, gt_d),
                     "ndist": float(np.mean(np.asarray(res.n_dist_comps)))})
    out["delta-emg"] = rows

    for kind in ("nsg", "tau_mg", "vamana", "nsw", "knn"):
        gb = index_baseline(kind)
        rows = []
        for l in WIDTHS:
            res = greedy_search(gb, q, k=K, l=l)
            rows.append({"param": l,
                         "rde": _rde(res.dists, gt_d),
                         "ndist": float(np.mean(np.asarray(res.n_dist_comps)))})
        out[kind] = rows

    # headline: #dist-comps needed for RDE ≤ 1e-2 (this corpus's floor sits
    # near 3e-3 at the swept widths; the paper's 1e-3 region needs its
    # 1M-point corpora)
    for method, rows in out.items():
        ok = [r for r in rows if r["rde"] <= 1e-2]
        if ok:
            best = min(ok, key=lambda r: r["ndist"])
            emit(f"exp5_ndist_at_rde1e-2_{method}", best["ndist"],
                 f"rde={best['rde']:.2e}")
        else:
            best = min(rows, key=lambda r: r["rde"])
            emit(f"exp5_ndist_at_rde1e-2_{method}", 0.0,
                 f"min_rde={best['rde']:.2e} (unreached)")
    common.save_json("exp5_error_analysis", out)
    return out


if __name__ == "__main__":
    run()
