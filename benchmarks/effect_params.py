"""Exp-3 (Fig. 5) + Exp-4 (Fig. 6): effect of construction parameters.

Exp-3: fixed global δ sweep (Algorithm 4 with constant δ) → QPS at 95%
recall, k=10.  Exp-4: adaptive-rule t sweep.  The paper's finding to
reproduce: a small nonzero δ (~0.04–0.06) beats both extremes, and the best
adaptive-t beats the best fixed-δ."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import BuildParams, build_approx, error_bounded_search

from . import common
from .common import BEAM, M_DEG, corpus, emit, recall, timed_qps

DELTAS = (0.0, 0.04, 0.1, 0.2)
TS = (8, 16, 32, 48)
ALPHAS = (1.0, 1.1, 1.4)


def _qps_at_recall(g, q, gt_i, target=0.95, k=10) -> tuple[float, float]:
    """Best QPS among α settings reaching the recall target (paper metric)."""
    best_qps, best_rec = 0.0, 0.0
    for alpha in ALPHAS:
        qps, res = timed_qps(
            lambda qq, a=alpha: error_bounded_search(g, qq, k=k, alpha=a,
                                                     l_max=192), q)
        rec = recall(res.ids, gt_i, k)
        best_rec = max(best_rec, rec)
        if rec >= target and qps > best_qps:
            best_qps = qps
    return best_qps, best_rec


def run() -> dict:
    base, queries, gt_d, gt_i = corpus()
    q = jnp.asarray(queries)
    out = {"fixed_delta": [], "adaptive_t": []}

    for delta in DELTAS:
        g = build_approx(base, BuildParams(max_degree=M_DEG, beam_width=BEAM,
                                           t=16, iters=2, delta=delta,
                                           block=512))
        qps, max_rec = _qps_at_recall(g, q, gt_i)
        deg = float(np.asarray(g.degrees()).mean())
        out["fixed_delta"].append({"delta": delta, "qps_at_r95": qps,
                                   "max_recall": max_rec, "mean_deg": deg})
        emit(f"exp3_delta_{delta}", 1e6 / qps if qps else 0.0,
             f"max_recall={max_rec:.3f};deg={deg:.1f}")

    for t in TS:
        g = build_approx(base, BuildParams(max_degree=M_DEG, beam_width=BEAM,
                                           t=t, iters=2, block=512))
        qps, max_rec = _qps_at_recall(g, q, gt_i)
        deg = float(np.asarray(g.degrees()).mean())
        out["adaptive_t"].append({"t": t, "qps_at_r95": qps,
                                  "max_recall": max_rec, "mean_deg": deg})
        emit(f"exp4_t_{t}", 1e6 / qps if qps else 0.0,
             f"max_recall={max_rec:.3f};deg={deg:.1f}")

    best_fixed = max((r["qps_at_r95"] for r in out["fixed_delta"]), default=0)
    best_adapt = max((r["qps_at_r95"] for r in out["adaptive_t"]), default=0)
    emit("exp4_adaptive_vs_fixed", 0.0,
         f"best_adaptive_qps={best_adapt:.0f};best_fixed_qps={best_fixed:.0f}")
    common.save_json("exp3_exp4_params", out)
    return out


if __name__ == "__main__":
    run()
