"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = 0.0 for pure
derived/ratio rows).  Full raw sweeps land in benchmarks/results/*.json.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run exp1 exp5  # named subsets
"""

import sys
import time


SECTIONS = {
    "exp1": ("qps_recall", "Exp-1 QPS vs recall (Fig. 3)"),
    "exp2": ("construction", "Exp-2 construction cost (Fig. 4)"),
    "exp34": ("effect_params", "Exp-3/4 effect of δ and t (Figs. 5-6)"),
    "exp5": ("error_analysis", "Exp-5 relative distance error (Fig. 7)"),
    "exp67": ("local_optimum", "Exp-6/7 local-optimum & δ' (Fig. 8)"),
    "exp8": ("scalability", "Exp-8 scalability (Fig. 9)"),
    "exp9": ("ablation", "Exp-9 ablation (Fig. 10)"),
    "retrieval": ("retrieval", "δ-EMQG behind recsys retrieval_cand"),
    "kernels": ("kernels_bench", "Pallas kernel microbench"),
    "roofline": ("roofline", "§Roofline table from the dry-run"),
}


def main() -> None:
    names = sys.argv[1:] or list(SECTIONS)
    print("name,us_per_call,derived")
    for key in names:
        mod_name, title = SECTIONS[key]
        print(f"# --- {title} ---")
        t0 = time.time()
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            print(f"{key}_FAILED,0.0,{type(e).__name__}:{str(e)[:120]}")
        print(f"# {key} done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
