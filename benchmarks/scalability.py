"""Exp-8 (Fig. 9): search time vs dataset size at fixed recall target.

The paper scales SIFT 1M→100M; on CPU we scale the synthetic corpus
1k→16k and verify near-log/linear growth of per-query work (hop count and
distance computations are the hardware-independent signal)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import BuildParams, build_approx, error_bounded_search
from repro.core.distances import brute_force_knn
from repro.data import clustered_vectors

from . import common
from .common import BEAM, M_DEG, T_PARAM, emit, recall, timed_qps

SIZES = (1000, 2000, 4000, 8000, 16000)
K = 10


def run() -> dict:
    rows = []
    for n in SIZES:
        base = clustered_vectors(n, common.DIM, common.N_CLUSTERS, seed=0)
        queries = clustered_vectors(128, common.DIM, common.N_CLUSTERS, seed=1)
        gt_d, gt_i = brute_force_knn(queries, base, K)
        g = build_approx(base, BuildParams(
            max_degree=M_DEG, beam_width=BEAM, t=T_PARAM, iters=2, block=512))
        q = jnp.asarray(queries)
        qps, res = timed_qps(
            lambda qq: error_bounded_search(g, qq, k=K, alpha=1.2, l_max=192), q)
        rows.append({
            "n": n,
            "qps": qps,
            "recall": recall(res.ids, gt_i, K),
            "ndist": float(np.mean(np.asarray(res.n_dist_comps))),
            "hops": float(np.mean(np.asarray(res.n_hops))),
        })
        emit(f"exp8_scal_n{n}", 1e6 / qps,
             f"recall={rows[-1]['recall']:.3f};ndist={rows[-1]['ndist']:.0f}")
    # growth factor of work per 2× data (paper: near-flat ⇒ ~log growth)
    ratios = [rows[i + 1]["ndist"] / rows[i]["ndist"] for i in range(len(rows) - 1)]
    emit("exp8_work_growth_per_2x", 0.0,
         f"ratios={';'.join(f'{r:.2f}' for r in ratios)}")
    common.save_json("exp8_scalability", rows)
    return rows


if __name__ == "__main__":
    run()
