"""Shared benchmark substrate: corpora, index cache, timing, CSV rows.

Scale note: the container is CPU-only, so ANN benchmarks run on a synthetic
SIFT-like corpus (clustered, LID-comparable) at n≈4–16k instead of SIFT1M,
and wall-clock numbers are CPU proxies — the *reproducible* claims are the
relative orderings and the recall/error/#distance-computation curves, which
are hardware-independent.  Absolute QPS for the paper's setting comes from
the roofline analysis of the dry-run (§Roofline).
"""

from __future__ import annotations

import json
import os
import time
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BuildParams,
    SearchParams,
    baselines,
    build_approx,
    build_emqg,
)
from repro.core.distances import brute_force_knn
from repro.data import clustered_vectors

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
N_BASE = int(os.environ.get("BENCH_N", 4000))
N_QUERY = int(os.environ.get("BENCH_Q", 200))
DIM = int(os.environ.get("BENCH_D", 32))
N_CLUSTERS = 48
K_GT = 100

M_DEG = 24
BEAM = 64
T_PARAM = 32
ITERS = 3


@lru_cache(maxsize=None)
def corpus(n=N_BASE, dim=DIM, seed=0):
    base = clustered_vectors(n, dim, N_CLUSTERS, seed=seed)
    queries = clustered_vectors(N_QUERY, dim, N_CLUSTERS, seed=seed + 1)
    gt_d, gt_i = brute_force_knn(queries, base, K_GT)
    return base, queries, gt_d, gt_i


@lru_cache(maxsize=None)
def index_emg(n=N_BASE, delta=None, t=T_PARAM, M=M_DEG, beam=BEAM, iters=ITERS):
    base, *_ = corpus(n)
    return build_approx(base, BuildParams(
        max_degree=M, beam_width=beam, t=t, iters=iters, delta=delta,
        block=512))


@lru_cache(maxsize=None)
def index_emqg(n=N_BASE, delta=None, t=T_PARAM, M=M_DEG, beam=BEAM, iters=2):
    base, *_ = corpus(n)
    return build_emqg(base, BuildParams(
        max_degree=M, beam_width=beam, t=t, iters=iters, delta=delta,
        block=512, align_degree=True))


@lru_cache(maxsize=None)
def index_baseline(kind: str, n=N_BASE, M=M_DEG, beam=BEAM):
    base, *_ = corpus(n)
    if kind == "knn":
        return baselines.build_knn_graph(base, k=M)
    if kind == "nsw":
        return baselines.build_nsw(base, max_degree=M, ef=beam)
    return baselines.BUILDERS[kind](base, max_degree=M, beam_width=beam)


def recall(ids, gt_i, k) -> float:
    ids = np.asarray(ids)[:, :k]
    return float(np.mean([
        len(set(ids[i].tolist()) & set(gt_i[i, :k].tolist())) / k
        for i in range(ids.shape[0])
    ]))


def timed_qps(fn, queries, repeats=3, hist=None):
    """Wall-clock QPS proxy (jit-warmed, best of `repeats`).

    ``hist`` — optional :class:`repro.obs.Histogram`; every repeat's
    elapsed batch time is observed into it so callers can report
    p50/p95/p99 from the same bucket math the serve layer uses."""
    out = fn(queries)                          # warm / trace
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(queries)
        jax.block_until_ready(out)
        elapsed = time.perf_counter() - t0
        if hist is not None:
            hist.observe(elapsed)
        best = min(best, elapsed)
    return queries.shape[0] / best, out


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path
