"""Exp-2 (Fig. 4): index construction time + memory footprint per method."""

from __future__ import annotations

import time

import numpy as np

from repro.core import BuildParams, baselines, build_approx, build_emqg
from repro.core.emqg import from_graph, memory_footprint

from . import common
from .common import BEAM, M_DEG, T_PARAM, corpus, emit


def _graph_bytes(g) -> int:
    return int(g.vectors.size * 4 + g.neighbors.size * 4)


def run() -> dict:
    base, *_ = corpus()
    out = {}
    builders = {
        "delta-emg": lambda: build_approx(base, BuildParams(
            max_degree=M_DEG, beam_width=BEAM, t=T_PARAM, iters=3, block=512)),
        "delta-emqg": lambda: build_emqg(base, BuildParams(
            max_degree=M_DEG, beam_width=BEAM, t=T_PARAM, iters=2, block=512,
            align_degree=True)),
        "nsg": lambda: baselines.build_nsg(base, max_degree=M_DEG,
                                           beam_width=BEAM),
        "tau_mg": lambda: baselines.build_taumg(base, max_degree=M_DEG,
                                                beam_width=BEAM),
        "vamana": lambda: baselines.build_vamana(base, max_degree=M_DEG,
                                                 beam_width=BEAM),
        "nsw": lambda: baselines.build_nsw(base, max_degree=M_DEG, ef=BEAM),
        "knn": lambda: baselines.build_knn_graph(base, k=M_DEG),
    }
    for name, fn in builders.items():
        t0 = time.perf_counter()
        idx = fn()
        dt = time.perf_counter() - t0
        if name == "delta-emqg":
            size = sum(memory_footprint(idx).values())
            g = idx.graph
        else:
            size = _graph_bytes(idx)
            g = idx
        deg = float(np.asarray(g.degrees()).mean())
        out[name] = {"build_s": dt, "bytes": size, "mean_degree": deg}
        emit(f"exp2_build_{name}", dt * 1e6,
             f"bytes={size};mean_deg={deg:.1f}")
    common.save_json("exp2_construction", out)
    return out


if __name__ == "__main__":
    run()
