"""The paper-technique integration benchmark: recsys `retrieval_cand`
served by (a) exact brute-force scoring vs (b) the δ-EMQG index over the
item-embedding corpus — recall@k of (b) against (a) plus the distance-
computation budget, i.e. what the index buys at serving time."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import BuildParams, build_emqg, error_bounded_probing_search
from repro.models import recsys as rs

from . import common
from .common import emit

N_ITEMS = int(__import__("os").environ.get("BENCH_RETR_N", 20000))
K = 100


def run() -> dict:
    arch = get_arch("mind")
    cfg = rs.MINDConfig(name="mind-bench", n_items=N_ITEMS, embed_dim=32,
                        n_interests=4, routing_iters=3, seq_len=20)
    params = rs.mind_init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B = 16
    hist = jnp.asarray(rng.integers(0, N_ITEMS, (B, cfg.seq_len)).astype(np.int32))
    mask = jnp.ones((B, cfg.seq_len), bool)
    cand = jnp.arange(N_ITEMS, dtype=jnp.int32)

    # (a) exact brute-force (the roofline-measurable dense path)
    t0 = time.perf_counter()
    sc_e, ids_e = rs.mind_retrieval(cfg, params, hist, mask, cand, k=K)
    jax.block_until_ready(ids_e)
    t0 = time.perf_counter()
    sc_e, ids_e = rs.mind_retrieval(cfg, params, hist, mask, cand, k=K)
    jax.block_until_ready(ids_e)
    exact_s = time.perf_counter() - t0

    # (b) the paper's index via the exact MIPS→L2 reduction (core.mips):
    # one augmented coordinate makes argmin-L2 ≡ argmax-dot, so the δ-EMG
    # error bound transfers to the inner-product retrieval.
    from repro.core.mips import build_mips, mips_search

    item_table = np.asarray(params["item_emb"])
    mips = build_mips(item_table, BuildParams(max_degree=24, beam_width=64,
                                              t=32, iters=2, block=1024))
    caps = rs.mind_user_interests(cfg, params, hist, mask)      # [B, Kc, d]
    flat_q = np.asarray(caps).reshape(-1, cfg.embed_dim)
    t0 = time.perf_counter()
    res = mips_search(mips, flat_q, k=K, alpha=1.2, l_max=256)
    jax.block_until_ready(res.ids)
    ann_s = time.perf_counter() - t0
    ids_per_interest = np.asarray(res.ids).reshape(B, cfg.n_interests, K)

    # merge per-interest candidates by true dot product
    recalls = []
    for b in range(B):
        cand_ids = np.unique(ids_per_interest[b].ravel())
        scores = np.asarray(caps[b]) @ item_table[cand_ids].T
        order = np.argsort(-scores.max(axis=0))[:K]
        got = set(cand_ids[order].tolist())
        want = set(np.asarray(ids_e[b]).tolist())
        recalls.append(len(got & want) / K)
    rec = float(np.mean(recalls))

    out = {
        "exact_s": exact_s, "ann_s": ann_s,
        "recall_vs_exact": rec,
        "exact_dist_comps": N_ITEMS * cfg.n_interests,
        "ann_exact_comps": float(np.mean(np.asarray(res.n_dist_comps))),
        "ann_approx_comps": float(np.mean(np.asarray(res.n_approx_comps))),
    }
    emit("retrieval_exact", exact_s * 1e6 / B, f"n_items={N_ITEMS}")
    emit("retrieval_emqg", ann_s * 1e6 / B,
         f"recall_vs_exact={rec:.3f};"
         f"comps={out['ann_exact_comps']:.0f}+{out['ann_approx_comps']:.0f}approx"
         f"_vs_{out['exact_dist_comps']}")
    common.save_json("retrieval_integration", out)
    return out


if __name__ == "__main__":
    run()
