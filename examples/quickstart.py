"""Quickstart: build a δ-EMG, run the error-bounded search, check the bound.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    BuildParams,
    SearchParams,
    build_approx,
    error_bounded_search,
    search,
    theorem4_delta_prime,
)
from repro.core.distances import brute_force_knn
from repro.data import clustered_vectors


def main():
    # 1. a SIFT-like corpus (synthetic — the container is offline)
    base = clustered_vectors(n=4000, dim=48, n_clusters=48, seed=0)
    queries = clustered_vectors(n=64, dim=48, n_clusters=48, seed=1)

    # 2. build the approximate δ-EMG (Algorithm 4)
    graph = build_approx(base, BuildParams(
        max_degree=24,   # M
        beam_width=64,   # L
        t=32,            # adaptive-δ neighborhood scale
        iters=3,
    ), verbose=True)
    print(f"mean out-degree: {float(np.asarray(graph.degrees()).mean()):.1f}")

    # 3. error-bounded top-k search (Algorithm 3) — α controls the bound
    res = error_bounded_search(graph, jnp.asarray(queries), k=10, alpha=1.5,
                               l_max=192)

    gt_d, gt_i = brute_force_knn(queries, base, 10)
    ids = np.asarray(res.ids)
    recall = np.mean([len(set(ids[i].tolist()) & set(gt_i[i].tolist())) / 10
                      for i in range(len(queries))])
    rde = float(np.mean((np.asarray(res.dists) - gt_d) / np.maximum(gt_d, 1e-9)))
    print(f"recall@10 = {recall:.4f}   relative-distance-error = {rde:.2e}")
    print(f"mean distance computations / query = "
          f"{float(np.mean(np.asarray(res.n_dist_comps))):.0f} (vs {len(base)} brute force)")

    # 4. the error-bounded certificate (Theorem 4)
    p = SearchParams(k=10, l0=10, l_max=192, alpha=1.5, adaptive=True,
                     max_hops=2048)
    _, cand_ids, cand_dists = search(graph, jnp.asarray(queries), p,
                                     with_candidates=True)
    found, dprime = theorem4_delta_prime(graph, jnp.asarray(queries),
                                         cand_ids, cand_dists, k=10, delta=0.05)
    found = np.asarray(found)
    print(f"local-optimum certificate found for {found.mean() * 100:.0f}% of "
          f"queries; mean certified δ' = "
          f"{float(np.asarray(dprime)[found].mean()):.4f}")


if __name__ == "__main__":
    main()
