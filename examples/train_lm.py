"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
the synthetic Markov language, with periodic checkpoints and resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--resume]

The model (d=512, 12 layers, vocab 8k) is ~0.1B params; loss should fall
from ln(8192) ≈ 9.0 toward the chain entropy ln(4) ≈ 1.39.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import lm_batch, make_markov_lm
from repro.models.transformer import LMConfig, init, loss_fn
from repro.optim import OptConfig
from repro.train import TrainState, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    cfg = LMConfig(name="lm-100m", n_layers=12, d_model=512, n_heads=8,
                   n_kv_heads=4, d_ff=1536, vocab=8192, dtype=jnp.float32)
    n_params = cfg.param_count()
    print(f"model: {n_params / 1e6:.0f}M params")

    opt = OptConfig(lr=3e-4, warmup_steps=30, total_steps=args.steps,
                    weight_decay=0.01)
    params = init(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(
        lambda p, b: loss_fn(cfg, p, b["tokens"], b["targets"]), opt),
        donate_argnums=(0,))
    state = TrainState.create(params, opt)

    mgr = CheckpointManager(args.ckpt_dir, every=100, keep=2)
    _, state = mgr.restore(state)
    start = int(state.step)
    if start:
        print(f"resumed at step {start}")

    lm = make_markov_lm(cfg.vocab, branch=4, seed=0)
    print(f"entropy floor: {lm.entropy():.3f} nats")
    t0, tokens_seen = time.time(), 0
    for s in range(start, args.steps):
        toks, tgts = lm_batch(lm, args.batch, args.seq, s, seed=0)
        state, m = step_fn(state, {"tokens": jnp.asarray(toks),
                                   "targets": jnp.asarray(tgts)})
        tokens_seen += toks.size
        if s % 20 == 0 or s == args.steps - 1:
            dt = time.time() - t0
            print(f"step {s:4d}  loss={float(m['loss']):.4f}  "
                  f"lr={float(m['lr']):.2e}  "
                  f"{tokens_seen / max(dt, 1e-9):.0f} tok/s")
        mgr.maybe_save(s + 1, state)
    mgr.wait()
    print("done.")


if __name__ == "__main__":
    main()
