"""The paper's technique inside a recommender: train a small MIND model on
synthetic click logs, then serve `retrieval_cand`-style queries two ways —
exact brute-force scoring vs the δ-EMQG index over the learned item
embeddings — and compare recall + distance budget.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BuildParams, build_emqg, error_bounded_probing_search
from repro.data import recsys_seq_batch
from repro.models import recsys as rs
from repro.optim import OptConfig
from repro.train import TrainState, make_train_step


def main():
    cfg = rs.MINDConfig(name="mind-demo", n_items=8192, embed_dim=32,
                        n_interests=4, routing_iters=3, seq_len=24, n_neg=16)
    params = rs.mind_init(cfg, jax.random.PRNGKey(0))
    opt = OptConfig(lr=3e-3, total_steps=200, warmup_steps=10)
    step_fn = jax.jit(make_train_step(
        lambda p, b: rs.mind_loss(cfg, p, b), opt))
    state = TrainState.create(params, opt)

    print("training MIND on planted-interest click logs…")
    for s in range(200):
        raw = recsys_seq_batch(64, step=s, n_items=cfg.n_items,
                               seq_len=cfg.seq_len, n_neg=cfg.n_neg)
        batch = {k: jnp.asarray(v) for k, v in raw.items()
                 if k in ("hist_items", "hist_mask", "target_item", "neg_items")}
        state, m = step_fn(state, batch)
        if s % 50 == 0 or s == 199:
            print(f"  step {s}: loss={float(m['loss']):.3f} "
                  f"acc={float(m['acc']):.3f}")

    params = state.params
    k = 50
    raw = recsys_seq_batch(16, step=9999, n_items=cfg.n_items,
                           seq_len=cfg.seq_len, n_neg=cfg.n_neg)
    hist = jnp.asarray(raw["hist_items"])
    mask = jnp.asarray(raw["hist_mask"])
    cand = jnp.arange(cfg.n_items, dtype=jnp.int32)

    # (a) exact: score every item (what retrieval_cand lowers for the dry-run)
    t0 = time.time()
    sc_e, ids_e = rs.mind_retrieval(cfg, params, hist, mask, cand, k=k)
    jax.block_until_ready(ids_e)
    print(f"exact scoring of {cfg.n_items} items: {time.time() - t0:.2f}s")

    # (b) the paper: δ-EMQG over the learned item-embedding table
    item_table = np.asarray(params["item_emb"])
    t0 = time.time()
    idx = build_emqg(item_table, BuildParams(max_degree=24, beam_width=64,
                                             t=32, iters=2, block=1024,
                                             align_degree=True))
    print(f"δ-EMQG build over item table: {time.time() - t0:.1f}s")
    caps = rs.mind_user_interests(cfg, params, hist, mask)
    flat_q = np.asarray(caps).reshape(-1, cfg.embed_dim)
    res = error_bounded_probing_search(idx, jnp.asarray(flat_q), k=k,
                                       alpha=1.2, l_max=256)
    per_int = np.asarray(res.ids).reshape(16, cfg.n_interests, k)

    recalls = []
    for b in range(16):
        got_ids = np.unique(per_int[b].ravel())
        scores = np.asarray(caps[b]) @ item_table[got_ids].T
        top = got_ids[np.argsort(-scores.max(0))[:k]]
        recalls.append(len(set(top.tolist()) &
                           set(np.asarray(ids_e[b]).tolist())) / k)
    print(f"δ-EMQG retrieval recall@{k} vs exact: {np.mean(recalls):.3f}")
    print(f"distance budget: "
          f"{float(np.mean(np.asarray(res.n_dist_comps))):.0f} exact + "
          f"{float(np.mean(np.asarray(res.n_approx_comps))):.0f} approx "
          f"per interest-query, vs {cfg.n_items} exact per user brute-force")


if __name__ == "__main__":
    main()
