"""End-to-end ANN *serving* driver (the paper's system in its deployment
shape): δ-EMQG + RaBitQ + probing search behind a batched request queue,
then the sharded multi-device variant of the same index.

    PYTHONPATH=src python examples/vector_serve.py
"""

import subprocess
import sys
import time

import jax.numpy as jnp
import numpy as np

from repro.core import BuildParams, SearchParams, build_emqg
from repro.core.distances import brute_force_knn
from repro.data import clustered_vectors
from repro.serve import AnnServer


def main():
    n, dim, k = 4000, 48, 10
    base = clustered_vectors(n, dim, 48, seed=0)
    queries = clustered_vectors(300, dim, 48, seed=1)
    gt_d, gt_i = brute_force_knn(queries, base, k)

    print("building δ-EMQG (RaBitQ codes + degree-aligned graph)…")
    t0 = time.time()
    idx = build_emqg(base, BuildParams(max_degree=24, beam_width=64, t=32,
                                       iters=2, block=1024, align_degree=True))
    print(f"  built in {time.time() - t0:.1f}s; code compression = "
          f"{base.nbytes / (np.asarray(idx.codes.codes).nbytes):.0f}×")

    srv = AnnServer(idx, SearchParams(k=k, l0=k, l_max=192, alpha=1.3,
                                      adaptive=True, max_hops=2048),
                    max_batch=64, buckets=(16, 64))
    srv.submit_many(queries)
    out = srv.drain()
    ids = np.stack([r[0] for r in out])
    rec = np.mean([len(set(ids[i].tolist()) & set(gt_i[i].tolist())) / k
                   for i in range(len(out))])
    print(f"served {srv.stats.n_requests} requests in {srv.stats.n_batches} "
          f"batches → recall@{k}={rec:.3f}, QPS={srv.stats.qps:.0f} (CPU proxy)")

    # ---- the sharded variant (4 shards on 8 virtual devices) ----
    print("\nsharded serving (subprocess with 8 virtual devices)…")
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.core import BuildParams, SearchParams
from repro.core.distributed import build_sharded, make_sharded_search
from repro.core.distances import brute_force_knn
from repro.data import clustered_vectors
base = clustered_vectors(4000, 48, 48, seed=0)
queries = clustered_vectors(300, 48, 48, seed=1)
gt_d, gt_i = brute_force_knn(queries, base, 10)
mesh = jax.make_mesh((4, 2), ("data", "model"))
sidx = build_sharded(base, 4, BuildParams(max_degree=24, beam_width=64, t=32,
                                          iters=2, block=1024,
                                          align_degree=True), quantized=True)
run = make_sharded_search(mesh, shard_axes=("data",), query_axis=None,
                          merge="all_gather", quantized=True)
params = SearchParams(k=10, l0=10, l_max=192, alpha=1.3, adaptive=True,
                      max_hops=2048)
ids, dists = run(sidx, jnp.asarray(queries), params)
ids = np.asarray(ids)
rec = np.mean([len(set(ids[i].tolist()) & set(gt_i[i].tolist()))/10
               for i in range(len(queries))])
print(f"  4-shard sharded index recall@10 = {rec:.3f}")
"""
    subprocess.run([sys.executable, "-c", code], check=True, cwd=".")


if __name__ == "__main__":
    main()
